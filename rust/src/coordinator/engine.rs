//! Execution engine: a dedicated OS thread that owns the thread-affine
//! PJRT [`Runtime`] and drains batches from the batcher.
//!
//! Jobs routed to an artifact run on PJRT; everything else runs on the
//! pure-Rust substrate through the unified
//! [`crate::attention::op::AttentionOp`] API (internally parallel over
//! heads and tiles via the [`crate::par`] fork/join pool — this tree is
//! rayon-free — so a single engine thread still saturates the machine).
//!
//! Streaming sessions: the engine owns a session table mapping
//! [`SessionId`] to its [`AttnCache`] (paged KV cache + appendable
//! decode sampling state).  Prefill ([`Work::Open`]) creates the entry;
//! decode steps check the entry out of the table, run one
//! `AttentionOp::decode_step`, and check it back in, so decode for
//! different sessions executes in parallel across the substrate workers
//! while each session's cache is mutated by one worker at a time.  On
//! shutdown, queued work is flushed with an explicit error response —
//! nothing is silently dropped — and the session table is cleared.
//!
//! **Memory budget** ([`CacheConfig`]): every session's cache draws its
//! pages from one shared [`PagePool`].  When the pool runs dry, an open
//! (or a decode append) first tries to LRU-evict an idle session — the
//! multi-tenant admission-control path — and only if nothing is
//! evictable returns an explicit backpressure error to the client.
//! Closing a session (or dropping the table at shutdown) returns its
//! pages to the pool's free list.  An optional idle-session TTL sweep
//! reclaims sessions whose clients dropped their handle without
//! `close_session` (the session-table leak fix), counted in
//! `sessions_reclaimed`.
//!
//! **Prefix registry**: alongside the session table the engine keeps a
//! small map of *pinned* prefix caches ([`Work::RegisterPrefix`]).  An
//! open carrying a prefix key forks the pinned cache — O(pages)
//! refcount bumps over the shared [`crate::linalg::PagePool`] frames,
//! copy-on-write on the partial tail page — so long common prompts
//! (system prompts, few-shot preambles, RAG scaffolding) are ingested
//! once and shared by every session.  Shared pages are charged to the
//! budget once; admission charges a forked open only for its private
//! tail.  Pinned prefixes are exempt from LRU eviction and the TTL
//! sweep ([`Work::ReleasePrefix`] unpins them).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::failpoint::{self, lock_recover};
use super::metrics::{CacheGauges, Metrics};
use super::request::{
    AttnJob, AttnResponse, Backend, DecodeJob, DecodeResponse, SessionId, DEADLINE_EXPIRED,
};
use super::router::{Route, RouteKind, RouterConfig};
use crate::attention::op::{self, AttnCache, AttnConfig, AttentionOp, CachePolicy, SeedPolicy};
use crate::linalg::{PagePool, QkvView, QuantMode, POOL_EXHAUSTED};
use crate::runtime::Runtime;

/// The unit of engine work.
pub enum Work {
    /// A one-shot attention job (the historical full-forward path).
    Full(AttnJob),
    /// Open a streaming session: prefill the prompt into a fresh cache
    /// — or, with `prefix` set, fork the pinned prefix cache in
    /// O(pages) refcount bumps and prefill only the suffix.
    Open { session: SessionId, job: AttnJob, prefix: Option<String> },
    /// One decode step for a live session.
    Decode(DecodeJob),
    /// Close a session, dropping its cache.
    Close { session: SessionId },
    /// Ingest a prompt into a pinned, shareable prefix cache under
    /// `key` (replacing any previous cache at that key).  `seq` is the
    /// submission order stamped by the server: register/release ops on
    /// one key may execute out of order across batch lanes, and the
    /// newest submission must win (see [`PrefixSlot`]).
    RegisterPrefix { key: String, seq: u64, job: AttnJob },
    /// Unpin a prefix cache.  Pages still shared by live forked
    /// sessions survive until those sessions drop them.
    ReleasePrefix { key: String, seq: u64 },
    /// Health probe: flows through the full submit → route → batch →
    /// execute pipeline and answers immediately, so a reply proves the
    /// whole path is live (not just that a queue accepted the message).
    Ping,
}

/// The response channel matching a [`Work`] variant (bounded-1 std
/// channels acting as oneshots).
pub enum Reply {
    Full(SyncSender<Result<AttnResponse, String>>),
    Decode(SyncSender<Result<DecodeResponse, String>>),
    /// health-probe ack (Err on shutdown flush)
    Ping(SyncSender<Result<(), String>>),
    /// fire-and-forget (session close)
    None,
}

/// One unit of work in flight, with its response channel.
pub struct WorkItem {
    pub work: Work,
    pub route: Route,
    pub submitted: Instant,
    /// Resolve with [`DEADLINE_EXPIRED`] instead of executing if this
    /// instant passes while the item is still queued.  `None` = no
    /// deadline.  Close/release ops ignore it (they must always run —
    /// skipping them would leak sessions or pinned pages).
    pub deadline: Option<Instant>,
    pub respond: Reply,
}

/// Messages to the engine thread.
pub enum EngineMsg {
    Batch(Vec<WorkItem>),
    Shutdown,
}

/// KV-cache memory policy of the engine: the shared page pool every
/// session draws from, the per-session eviction policy, and the
/// idle-session TTL.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// f32 elements per page frame in the shared pool.  Uniform frames
    /// mean a page freed by any session is reusable by any other
    /// regardless of its `[heads, d]` shape; rows-per-page for a shape
    /// is `page_elems / (3·heads·d)` (K, V, and the pre-scaled K mirror
    /// share the frame).
    pub page_elems: usize,
    /// Global budget of outstanding pages across every session
    /// (None = unbounded, the default).  Provisioning note: a prefill
    /// transiently holds every prompt page before a sliding window
    /// trims it, so the budget must cover the largest expected prompt
    /// (`ceil(prompt_rows / rows_per_page)`) — opens that cannot ever
    /// fit are rejected up front without evicting anyone.  Steady-state
    /// decode under a window then needs only
    /// `window/rows_per_page + sink pages + 1` per session (the slide
    /// recycles its own pages before touching the pool).
    pub budget_pages: Option<usize>,
    /// eviction policy applied to every session cache
    pub policy: CachePolicy,
    /// reclaim sessions idle longer than this (None = off, the
    /// default).  The sweep runs on the engine thread at ~ttl/4.
    pub idle_ttl: Option<Duration>,
    /// Graceful-degradation window: when a decode step keeps hitting
    /// pool exhaustion after backoff and LRU eviction, the session is
    /// degraded **once** to a sliding window of at most this many rows
    /// (sink pinning preserved) and decode resumes — trading context
    /// for availability before the final admission-reject shed.
    /// None (the default) disables the degrade rung of the ladder.
    pub degrade_window: Option<usize>,
    /// Frozen-page KV compression mode ([`QuantMode::Off`] by default):
    /// with `F16`/`Int8`, every full page is compressed at the moment it
    /// freezes (and its f32 planes — including the pre-scaled K mirror —
    /// dropped), shrinking its resident footprint to ~1/3 (f16) or ~1/6
    /// (int8) of the f32 page and multiplying what a byte budget holds.
    /// Sink pages and the hot partial tail stay f32; decode streams
    /// quantized pages through fused dequant kernels.  A `page_freeze`
    /// failpoint fault degrades just that page back to f32
    /// ([`crate::linalg::PoolStats::quant_fallbacks`]).
    pub quant: QuantMode,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            // 64 rows per page at the serving default h·d = 4·64
            page_elems: 3 * 256 * 64,
            budget_pages: None,
            policy: CachePolicy::Full,
            idle_ttl: None,
            degrade_window: None,
            quant: QuantMode::Off,
        }
    }
}

/// A live session: the compiled op config it was opened with plus its
/// KV cache.  `None` in the table means "checked out by a worker".
pub(crate) struct SessionEntry {
    pub(crate) cfg: AttnConfig,
    pub(crate) heads: usize,
    pub(crate) d: usize,
    pub(crate) cache: AttnCache,
    /// last open/decode activity — the LRU-eviction and TTL-sweep key
    pub(crate) last_used: Instant,
    /// already degraded to the tighter window (each session degrades at
    /// most once; after that, sustained exhaustion sheds)
    pub(crate) degraded: bool,
}

pub(crate) type SessionMap = Arc<Mutex<HashMap<SessionId, Option<SessionEntry>>>>;

/// A pinned, shareable prompt prefix: sessions opened with its key fork
/// this cache's block table (refcount bumps, no copies) instead of
/// re-ingesting the prefix.  Pinned entries are never LRU-evicted or
/// TTL-swept — they are released explicitly.
pub(crate) struct PrefixEntry {
    /// submission sequence of the registration (newest wins)
    seq: u64,
    cfg: AttnConfig,
    heads: usize,
    d: usize,
    cache: AttnCache,
}

/// State of one prefix key.  Register and release ride different batch
/// lanes, so they can execute out of submission order; each op carries
/// its server-stamped sequence and the **newest submission wins**: a
/// release that overtakes its register leaves a [`PrefixSlot::Released`]
/// tombstone the older register refuses to overwrite — without this, a
/// reordered release would remove nothing and the late register would
/// pin pages forever (prefixes are exempt from LRU/TTL reclamation).
pub(crate) enum PrefixSlot {
    /// pinned and forkable
    Live(PrefixEntry),
    /// released at this submission sequence
    Released(u64),
}

pub(crate) type PrefixMap = Arc<Mutex<HashMap<String, PrefixSlot>>>;

/// Everything a worker needs to execute engine work — cloned per
/// worker thread.
#[derive(Clone)]
pub(crate) struct EngineCtx {
    pub(crate) rc: RouterConfig,
    pub(crate) cache: CacheConfig,
    pub(crate) pool: PagePool,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) sessions: SessionMap,
    pub(crate) prefixes: PrefixMap,
}

/// How long session checkout/close waits for an in-flight decode step
/// to check its entry back in before giving up.  Bounds the wait so a
/// wedged session (e.g. a panicked step that never checked in) degrades
/// to an explicit error instead of spinning a worker forever.
const SESSION_WAIT: std::time::Duration = std::time::Duration::from_secs(10);

/// Take a session's entry out of the table, waiting (bounded) if
/// another worker has it checked out.  Errors if the session does not
/// exist or stays checked out past [`SESSION_WAIT`].
pub(crate) fn checkout(sessions: &SessionMap, id: SessionId) -> Result<SessionEntry, String> {
    failpoint::hit("session_checkout")?;
    let deadline = Instant::now() + SESSION_WAIT;
    loop {
        {
            let mut map = lock_recover(sessions);
            match map.get_mut(&id) {
                None => return Err(format!("unknown session {id}")),
                Some(slot) => {
                    if let Some(entry) = slot.take() {
                        return Ok(entry);
                    }
                }
            }
        }
        if Instant::now() >= deadline {
            return Err(format!("session {id} busy past {SESSION_WAIT:?}; giving up"));
        }
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
}

/// Return a checked-out entry.  If the session was closed (or the table
/// cleared on shutdown) while it was out, the entry is dropped.
pub(crate) fn checkin(sessions: &SessionMap, id: SessionId, entry: SessionEntry) {
    let mut map = lock_recover(sessions);
    if let Some(slot) = map.get_mut(&id) {
        *slot = Some(entry);
    }
}

/// Remove a session, waiting (bounded) for any in-flight decode step to
/// check it back in first.  Past the deadline the slot is removed
/// anyway — a late checkin against the removed id just drops the entry
/// (see [`checkin`]).  Idempotent.
fn close_session(sessions: &SessionMap, id: SessionId) {
    let deadline = Instant::now() + SESSION_WAIT;
    loop {
        {
            let mut map = lock_recover(sessions);
            let checked_out = matches!(map.get(&id), Some(None));
            if !checked_out || Instant::now() >= deadline {
                // absent (already closed), present-and-idle, or wedged
                // past the deadline: remove
                map.remove(&id);
                return;
            }
            // checked out: drop the lock and wait for checkin
        }
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
}

/// Largest block size ≤ `target` that divides n (≥ 1).  Delegates to
/// the O(√n) divisor enumeration in [`crate::attention::op::fit_block`]
/// (the old downward scan here was O(n) per job for prime n).
pub fn pick_block(n: usize, target: usize) -> usize {
    op::fit_block(n, target)
}

/// The substrate [`AttnConfig`] for one routed job: the route's
/// algorithm choice plus the router's block/sample/base targets.  All
/// shape fitting (divisor blocks, prime-n exact fallback, causal
/// dispatch) happens inside the op's documented policy.
pub fn substrate_config(job: &AttnJob, kind: RouteKind, rc: &RouterConfig) -> AttnConfig {
    let backend = match (kind, job.causal) {
        (RouteKind::Exact, _) => op::Backend::Flash,
        (RouteKind::Hyper, false) => op::Backend::Hyper,
        (RouteKind::Hyper, true) => op::Backend::CausalHyper,
    };
    AttnConfig {
        backend,
        causal: job.causal,
        block: rc.block.max(1),
        samples: rc.samples,
        causal_base: rc.causal_base,
        seed: SeedPolicy::PerHead(job.seed as u64),
        // the router's policy carries through to the op, so the
        // degenerate-block guard, the decode thresholds, and any
        // threshold tuning share one source of truth
        auto: rc.auto_policy(),
        ..Default::default()
    }
}

/// Evict the least-recently-used *idle* session to reclaim its pages
/// for new work.  Checked-out sessions (slot = None) and `skip` are
/// never victims.  Returns false when nothing was evictable.
fn evict_lru_session(ctx: &EngineCtx, skip: Option<SessionId>) -> bool {
    // take the victim out under the lock, but drop it (one pool free
    // per page) after releasing the table — concurrent decode
    // checkouts must not stall behind a large cache teardown
    let victim = {
        let mut map = lock_recover(&ctx.sessions);
        let id = map
            .iter()
            .filter(|(id, slot)| Some(**id) != skip && slot.is_some())
            .min_by_key(|(_, slot)| slot.as_ref().expect("filtered Some").last_used)
            .map(|(id, _)| *id);
        id.map(|id| map.remove(&id).expect("victim present"))
    };
    match victim {
        Some(entry) => {
            drop(entry); // frees its pages back to the pool
            ctx.metrics.sessions_evicted.fetch_add(1, Relaxed);
            true
        }
        None => false,
    }
}

/// Reclaim sessions idle past the TTL — the leak fix for clients that
/// dropped their handle without `close_session`.  Checked-out sessions
/// are in use by definition and are skipped.
fn sweep_idle(ctx: &EngineCtx, ttl: Duration) {
    let now = Instant::now();
    // collect + detach under the lock; tear the caches down (page
    // frees) after releasing it
    let dead = {
        let mut map = lock_recover(&ctx.sessions);
        let ids: Vec<SessionId> = map
            .iter()
            .filter(|(_, slot)| {
                slot.as_ref().is_some_and(|e| now.duration_since(e.last_used) >= ttl)
            })
            .map(|(id, _)| *id)
            .collect();
        ids.into_iter().filter_map(|id| map.remove(&id)).collect::<Vec<_>>()
    };
    let n = dead.len() as u64;
    drop(dead); // frees the reclaimed sessions' pages
    if n > 0 {
        ctx.metrics.sessions_reclaimed.fetch_add(n, Relaxed);
    }
}

/// Snapshot the paged-cache subsystem (pool counters + per-session and
/// per-prefix residency) for status output.  Shared pages are counted
/// once (`pages_in_use` is physical frames); `pages_shared` is how many
/// of them more than one owner still references.
pub(crate) fn cache_gauges(
    sessions: &SessionMap,
    prefixes: &PrefixMap,
    pool: &PagePool,
    metrics: &Metrics,
) -> CacheGauges {
    let s = pool.stats();
    let map = lock_recover(sessions);
    let mut per_session: Vec<(u64, usize, usize)> = map
        .iter()
        .map(|(id, slot)| match slot {
            Some(e) => (*id, e.cache.kv().resident_pages(), e.cache.len()),
            None => (*id, 0, 0), // checked out right now
        })
        .collect();
    per_session.sort_by_key(|&(id, _, _)| id);
    let degraded_live = map
        .values()
        .filter(|slot| slot.as_ref().is_some_and(|e| e.degraded))
        .count() as u64;
    drop(map);
    let pmap = lock_recover(prefixes);
    let mut per_prefix: Vec<(String, usize, usize)> = pmap
        .iter()
        .filter_map(|(key, slot)| match slot {
            PrefixSlot::Live(e) => {
                Some((key.clone(), e.cache.kv().resident_pages(), e.cache.len()))
            }
            PrefixSlot::Released(_) => None,
        })
        .collect();
    per_prefix.sort_by(|a, b| a.0.cmp(&b.0));
    drop(pmap);
    CacheGauges {
        page_elems: s.page_elems,
        budget_pages: s.budget,
        pages_in_use: s.outstanding,
        pages_shared: s.shared,
        cow_copies: s.cows,
        pages_free: s.free,
        peak_pages: s.peak,
        pool_allocs: s.allocs,
        pool_reuses: s.reuses,
        pool_rejects: s.rejects,
        kv_quant: s.quant.name(),
        bytes_in_use: s.bytes_in_use,
        bytes_peak: s.bytes_peak,
        bytes_saved_quant: s.bytes_saved_quant,
        quant_pages: s.quant_pages,
        quant_fallbacks: s.quant_fallbacks,
        sessions_evicted: metrics.sessions_evicted.load(Relaxed),
        sessions_reclaimed: metrics.sessions_reclaimed.load(Relaxed),
        admission_rejects: metrics.admission_rejects.load(Relaxed),
        per_session,
        per_prefix,
        degraded_sessions: degraded_live,
        failpoints: failpoint::counters().into_iter().filter(|(_, n)| *n > 0).collect(),
        poison_recovered: failpoint::poison_recovered(),
        batch_mean_occupancy: metrics.batch_occupancy.mean_us(),
        sched_serial_fallbacks: metrics.sched_serial_fallbacks.load(Relaxed),
        draft_lanes: metrics.draft_lanes.load(Relaxed) as usize,
        draft_proposed: metrics.draft_proposed.load(Relaxed),
        draft_accepted: metrics.draft_accepted.load(Relaxed),
        draft_rollbacks: metrics.draft_rollbacks.load(Relaxed),
        chunked_ingests: metrics.chunked_ingests.load(Relaxed),
        prefill_chunks: metrics.prefill_chunks.load(Relaxed),
        ingest_serial_fallbacks: metrics.ingest_serial_fallbacks.load(Relaxed),
    }
}

/// Bound on LRU-eviction retries for one admission attempt.
const MAX_ADMISSION_EVICTIONS: usize = 64;

/// The one admission retry state machine every prompt ingest goes
/// through: build a cache via `make_cache` (fresh, or a validated
/// prefix fork — re-invoked per attempt so forks are re-validated),
/// prefill the job into it, and on pool exhaustion LRU-evict an idle
/// session and retry (bounded), else reject with explicit
/// backpressure.
fn admit_prefill<F>(
    job: &AttnJob,
    attn: &AttentionOp,
    ctx: &EngineCtx,
    mut make_cache: F,
) -> Result<(AttnCache, Vec<f32>), String>
where
    F: FnMut() -> Result<AttnCache, String>,
{
    let mut attempts = 0usize;
    loop {
        let mut cache = make_cache()?;
        let view = QkvView::new(job.heads, job.n, job.d, &job.q, &job.k, &job.v)?;
        match attn.prefill(&mut cache, view) {
            Ok(out) => return Ok((cache, out.into_out())),
            Err(e) if e.contains(POOL_EXHAUSTED) => {
                drop(cache); // return the partial allocation first
                if attempts < MAX_ADMISSION_EVICTIONS && evict_lru_session(ctx, None) {
                    attempts += 1;
                    continue;
                }
                return Err(reject_admission(ctx, e));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Admission-controlled ingest into a **fresh** cache (plain opens and
/// prefix registration): budget feasibility precheck (a prompt that can
/// never fit is rejected before evicting anyone — prefill transiently
/// needs every prompt page; the window trims only after the append),
/// then the shared [`admit_prefill`] retry loop.  `what` labels the
/// feasibility error ("prompt" / "prefix").
fn prefill_with_admission(
    job: &AttnJob,
    attn: &AttentionOp,
    what: &str,
    ctx: &EngineCtx,
) -> Result<(AttnCache, Vec<f32>), String> {
    let rows_page = ctx.cache.page_elems / (3 * job.heads * job.d).max(1);
    if let (Some(budget), true) = (ctx.cache.budget_pages, rows_page > 0) {
        let needed = job.n.div_ceil(rows_page);
        if needed > budget {
            return Err(reject_admission(
                ctx,
                format!("{what} needs {needed} pages, pool budget is {budget}"),
            ));
        }
    }
    admit_prefill(job, attn, ctx, || {
        AttnCache::with_pool(job.heads, job.d, ctx.cache.policy, &ctx.pool)
    })
}

/// Prefill a session's prompt into a fresh cache (pages from the shared
/// pool) and register it in the session table.  With a `prefix` key the
/// session instead **forks** the pinned prefix cache — O(pages)
/// refcount bumps, shared pages charged once — and prefills only the
/// suffix (`job` q/k/v are the continuation rows at positions
/// `prefix_len..`); admission then charges the session only for its
/// private tail.  Pool exhaustion evicts idle sessions LRU-first; with
/// nothing left to evict the open is rejected with explicit
/// backpressure.
fn run_open(
    session: SessionId,
    job: &AttnJob,
    prefix: Option<&str>,
    kind: RouteKind,
    ctx: &EngineCtx,
) -> Result<Vec<f32>, String> {
    failpoint::hit("open_job")?;
    let cfg = substrate_config(job, kind, &ctx.rc);
    let attn = cfg.build()?;
    let (cache, out) = match prefix {
        None => prefill_with_admission(job, &attn, "prompt", ctx)?,
        Some(key) => fork_prefix_with_admission(job, &attn, key, &cfg, ctx)?,
    };
    lock_recover(&ctx.sessions).insert(
        session,
        Some(SessionEntry {
            cfg,
            heads: job.heads,
            d: job.d,
            cache,
            last_used: Instant::now(),
            degraded: false,
        }),
    );
    Ok(out)
}

/// The forked-open path: validation, private-tail admission math, and
/// the fork all happen under ONE prefix-map lock acquisition (and are
/// re-done on every eviction retry), so a concurrent RegisterPrefix
/// replacing the key can never hand this open a cache that was not the
/// one validated and charged.  Only the private tail (the COW'd
/// partial page + the suffix's fresh pages) is charged on top of the
/// pinned prefix pages nothing can reclaim.
fn fork_prefix_with_admission(
    job: &AttnJob,
    attn: &AttentionOp,
    key: &str,
    cfg: &AttnConfig,
    ctx: &EngineCtx,
) -> Result<(AttnCache, Vec<f32>), String> {
    let rows_page = ctx.cache.page_elems / (3 * job.heads * job.d).max(1);
    admit_prefill(job, attn, ctx, || {
        let map = lock_recover(&ctx.prefixes);
        let Some(PrefixSlot::Live(entry)) = map.get(key) else {
            return Err(format!("unknown prefix {key:?}"));
        };
        if entry.heads != job.heads || entry.d != job.d {
            return Err(format!(
                "prefix {key:?} shape (h={}, d={}) != open shape (h={}, d={})",
                entry.heads, entry.d, job.heads, job.d
            ));
        }
        if entry.cfg.causal != cfg.causal || entry.cfg.scale != cfg.scale {
            return Err(format!(
                "prefix {key:?} was ingested under an incompatible config \
                 (causal={}, scale={:?})",
                entry.cfg.causal, entry.cfg.scale
            ));
        }
        if let (Some(budget), true) = (ctx.cache.budget_pages, rows_page > 0) {
            let plen = entry.cache.len();
            let needed = entry.cache.kv().resident_pages()
                + (plen + job.n).div_ceil(rows_page)
                - plen / rows_page;
            if needed > budget {
                return Err(reject_admission(
                    ctx,
                    format!(
                        "prefix + private tail needs {needed} pages, \
                         pool budget is {budget}"
                    ),
                ));
            }
        }
        Ok(entry.cache.fork())
    })
}

/// Ingest a prompt into a pinned prefix cache under `key` (the cache
/// future sessions fork from).  Replaces any previous entry at the key,
/// releasing its handles — unless a *newer* register or release for the
/// key already landed (sequence comparison), in which case the freshly
/// built cache is dropped instead of resurrecting the key: the prompt's
/// attention output is still returned, but nothing stays pinned.  Pool
/// exhaustion follows the same LRU-evict / backpressure path as an
/// open.
fn run_register_prefix(
    key: &str,
    seq: u64,
    job: &AttnJob,
    kind: RouteKind,
    ctx: &EngineCtx,
) -> Result<Vec<f32>, String> {
    failpoint::hit("prefix_register")?;
    let cfg = substrate_config(job, kind, &ctx.rc);
    let attn = cfg.build()?;
    let (cache, out) = prefill_with_admission(job, &attn, "prefix", ctx)?;
    let old = {
        let mut map = lock_recover(&ctx.prefixes);
        let superseded = match map.get(key) {
            Some(PrefixSlot::Live(e)) => e.seq > seq,
            Some(PrefixSlot::Released(s)) => *s > seq,
            None => false,
        };
        if superseded {
            None // drop the fresh cache below; the newer op won
        } else {
            map.insert(
                key.to_string(),
                PrefixSlot::Live(PrefixEntry {
                    seq,
                    cfg,
                    heads: job.heads,
                    d: job.d,
                    cache,
                }),
            )
        }
    };
    drop(old); // a replaced prefix releases its handles outside the lock
    Ok(out)
}

/// Apply a release op: tombstone the key at `seq` unless a newer
/// register already landed.  The dropped cache's handles are released
/// outside the lock.
fn run_release_prefix(key: String, seq: u64, ctx: &EngineCtx) {
    // Infallible seam (release must not fail): `err` unwinds instead
    // and is caught by the per-job isolation.
    failpoint::hit_unwind("prefix_release");
    let old = {
        let mut map = lock_recover(&ctx.prefixes);
        let newer_exists = match map.get(&key) {
            Some(PrefixSlot::Live(e)) => e.seq > seq,
            Some(PrefixSlot::Released(s)) => *s >= seq,
            None => false,
        };
        if newer_exists {
            None
        } else {
            map.insert(key, PrefixSlot::Released(seq))
        }
    };
    drop(old);
}

/// Count and uniformly shape an admission rejection (same wrapper
/// whether it came from the feasibility precheck, an empty eviction
/// candidate list, or the retry bound).
fn reject_admission(ctx: &EngineCtx, why: String) -> String {
    ctx.metrics.admission_rejects.fetch_add(1, Relaxed);
    format!("session admission rejected: {why}")
}

/// A long prompt ingest the scheduler interleaves with decode ticks:
/// one ≤ `chunk`-row piece per tick through [`AttentionOp::prefill`],
/// so a 131k-row open no longer stalls the decode lanes for its whole
/// wall-time.  Above the op's `prefill_hyper_threshold` each chunk runs
/// the chunk-appendable causal-hyper estimator (near-linear in the
/// chunk, not the resident prefix); below it the exact streaming path
/// serves each chunk.  The assembled output is exactly what the same
/// chunk schedule would produce through the monolithic path.
///
/// Failure semantics mirror the monolithic open: validation errors and
/// admission rejects resolve the ticket at [`ChunkedIngest::begin`];
/// mid-ingest pool exhaustion LRU-evicts and retries per chunk (the KV
/// append is atomic on exhaustion); a `prefill_chunk` fault degrades
/// the ingest to one serial pass over its remaining rows
/// (`ingest_serial_fallbacks`); a panicked chunk fails only this
/// ingest's ticket and drops its partial cache.  No session is
/// registered until [`ChunkedIngest::finish`], so there is never a
/// half-ingested entry to quarantine.
pub(crate) struct ChunkedIngest {
    /// `Some` for [`Work::Open`] (registered at finish), `None` for a
    /// one-shot [`Work::Full`] (cache dropped at finish)
    session: Option<SessionId>,
    job: AttnJob,
    cfg: AttnConfig,
    attn: AttentionOp,
    cache: AttnCache,
    /// assembled `[heads, n, d]` output, written chunk by chunk
    out: Vec<f32>,
    /// rows ingested so far
    fed: usize,
    /// target rows per tick (clamped per chunk for sink-less windows)
    chunk: usize,
    respond: Reply,
    deadline: Option<Instant>,
    queue_us: u64,
    exec_start: Instant,
}

impl ChunkedIngest {
    /// Convert an eligible work item into a chunked ingest.
    /// `Err(Some(item))` hands back a non-eligible item (pings, closes,
    /// prefix work, short / non-causal / forked prompts) for in-place
    /// execution; `Err(None)` means the item was consumed here (expired
    /// deadline, or a validation/admission failure already resolved the
    /// ticket).
    pub(crate) fn begin(
        item: WorkItem,
        chunk: usize,
        ctx: &EngineCtx,
    ) -> Result<ChunkedIngest, Option<WorkItem>> {
        let eligible = chunk > 0
            && match &item.work {
                Work::Open { job, prefix: None, .. } => job.causal && job.n > chunk,
                Work::Full(job) => job.causal && job.n > chunk,
                _ => false,
            };
        if !eligible {
            return Err(Some(item));
        }
        let Some(item) = expire_if_late(item, &ctx.metrics) else { return Err(None) };
        let WorkItem { work, route, submitted, deadline, respond } = item;
        let queue_us = submitted.elapsed().as_micros() as u64;
        let (session, job) = match work {
            Work::Open { session, job, .. } => (Some(session), job),
            Work::Full(job) => (None, job),
            _ => unreachable!("eligibility checked above"),
        };
        let started = catch_job(&ctx.metrics, || {
            failpoint::hit(if session.is_some() { "open_job" } else { "full_job" })?;
            QkvView::new(job.heads, job.n, job.d, &job.q, &job.k, &job.v)?;
            let cfg = substrate_config(&job, route.kind, &ctx.rc);
            let attn = cfg.build()?;
            // same up-front feasibility check as a monolithic open: a
            // prompt that can never fit under a Full policy is rejected
            // before evicting anyone
            let rows_page = ctx.cache.page_elems / (3 * job.heads * job.d).max(1);
            if let (Some(budget), true, CachePolicy::Full) =
                (ctx.cache.budget_pages, rows_page > 0, ctx.cache.policy)
            {
                let needed = job.n.div_ceil(rows_page);
                if needed > budget {
                    return Err(reject_admission(
                        ctx,
                        format!("prompt needs {needed} pages, pool budget is {budget}"),
                    ));
                }
            }
            let cache = AttnCache::with_pool(job.heads, job.d, ctx.cache.policy, &ctx.pool)?;
            Ok((cfg, attn, cache))
        });
        match started {
            Ok((cfg, attn, cache)) => {
                ctx.metrics.chunked_ingests.fetch_add(1, Relaxed);
                let out = vec![0.0f32; job.heads * job.n * job.d];
                Ok(ChunkedIngest {
                    session,
                    job,
                    cfg,
                    attn,
                    cache,
                    out,
                    fed: 0,
                    chunk,
                    respond,
                    deadline,
                    queue_us,
                    exec_start: Instant::now(),
                })
            }
            Err(e) => {
                ctx.metrics.jobs_failed.fetch_add(1, Relaxed);
                if let Reply::Full(tx) = respond {
                    let _ = tx.send(Err(e));
                }
                Err(None)
            }
        }
    }

    /// Feed rows: one ≤ `chunk`-row piece per call normally, or every
    /// remaining row in one serial pass when a `prefill_chunk` fault
    /// degrades this ingest.  `Ok(true)` = all rows ingested (call
    /// [`Self::finish`]).
    pub(crate) fn step(&mut self, ctx: &EngineCtx) -> Result<bool, String> {
        if let Some(dl) = self.deadline {
            if Instant::now() >= dl {
                ctx.metrics.deadline_expired.fetch_add(1, Relaxed);
                return Err(format!(
                    "{DEADLINE_EXPIRED} (ingested {} of {} rows)",
                    self.fed, self.job.n
                ));
            }
        }
        let serial = failpoint::hit("prefill_chunk").is_err();
        if serial {
            // degradation, not death: finish the prompt in one serial
            // pass (the PR 6 ladder — shed interleaving, keep serving)
            ctx.metrics.ingest_serial_fallbacks.fetch_add(1, Relaxed);
        }
        loop {
            let left = self.job.n - self.fed;
            let mut c = if serial { left } else { left.min(self.chunk) };
            // a sink-less sliding window rejects an appended chunk
            // larger than the window (it would evict its own queries'
            // keys mid-append); clamp so a windowed open of a long
            // prompt succeeds instead of bouncing off that guard
            if self.fed > 0 {
                if let CachePolicy::SlidingWindow { window, sink: 0 } = self.cache.policy() {
                    c = c.min(window.max(1));
                }
            }
            self.feed(c, ctx)?;
            if self.fed == self.job.n {
                return Ok(true);
            }
            if !serial {
                return Ok(false);
            }
        }
    }

    /// Ingest one piece of `c` rows through the op, retrying pool
    /// exhaustion with LRU eviction (the KV append is atomic on
    /// exhaustion, so a retry re-runs the identical append).
    fn feed(&mut self, c: usize, ctx: &EngineCtx) -> Result<(), String> {
        let (h, n, d) = (self.job.heads, self.job.n, self.job.d);
        let lo = self.fed * d;
        let x = QkvView::strided(
            h,
            c,
            d,
            n * d,
            &self.job.q[lo..],
            &self.job.k[lo..],
            &self.job.v[lo..],
        )?;
        let mut evictions = 0usize;
        let out = loop {
            match self.attn.prefill(&mut self.cache, x) {
                Ok(out) => break out.into_out(),
                Err(e) if e.contains(POOL_EXHAUSTED) => {
                    if evictions < MAX_ADMISSION_EVICTIONS && evict_lru_session(ctx, None) {
                        evictions += 1;
                        continue;
                    }
                    return Err(reject_admission(ctx, e));
                }
                Err(e) => return Err(e),
            }
        };
        // chunk output is packed [h, c, d]; splice it into the
        // assembled [h, n, d] buffer at this chunk's row offset
        for head in 0..h {
            let src = head * c * d;
            let dst = head * n * d + lo;
            self.out[dst..dst + c * d].copy_from_slice(&out[src..src + c * d]);
        }
        self.fed += c;
        ctx.metrics.prefill_chunks.fetch_add(1, Relaxed);
        Ok(())
    }

    /// All rows ingested: register the session (opens) and resolve the
    /// ticket with the assembled output.
    pub(crate) fn finish(self, ctx: &EngineCtx) {
        let ChunkedIngest {
            session, job, cfg, cache, out, respond, queue_us, exec_start, ..
        } = self;
        let exec_us = exec_start.elapsed().as_micros() as u64;
        let metrics = &*ctx.metrics;
        metrics.queue_latency.record(queue_us);
        metrics.exec_latency.record(exec_us);
        metrics.e2e_latency.record(queue_us + exec_us);
        metrics.substrate_jobs.fetch_add(1, Relaxed);
        metrics.jobs_completed.fetch_add(1, Relaxed);
        if let Some(id) = session {
            lock_recover(&ctx.sessions).insert(
                id,
                Some(SessionEntry {
                    cfg,
                    heads: job.heads,
                    d: job.d,
                    cache,
                    last_used: Instant::now(),
                    degraded: false,
                }),
            );
            metrics.sessions_opened.fetch_add(1, Relaxed);
        }
        if let Reply::Full(tx) = respond {
            let _ = tx.send(Ok(AttnResponse {
                id: job.id,
                out,
                backend: Backend::Substrate,
                queue_us,
                exec_us,
            }));
        }
    }

    /// Resolve the ticket with `e` and drop the partial cache (its
    /// pages return to the pool).  No session was registered yet, so
    /// there is nothing to quarantine.
    pub(crate) fn fail(self, e: String, ctx: &EngineCtx) {
        let metrics = &*ctx.metrics;
        let exec_us = self.exec_start.elapsed().as_micros() as u64;
        metrics.queue_latency.record(self.queue_us);
        metrics.exec_latency.record(exec_us);
        // failed ingests (admission sheds included) stay in the e2e tail
        metrics.e2e_latency.record(self.queue_us + exec_us);
        metrics.jobs_failed.fetch_add(1, Relaxed);
        if let Reply::Full(tx) = self.respond {
            let _ = tx.send(Err(e));
        }
    }
}

/// Backoff schedule for transient decode-time pool exhaustion: another
/// session may be releasing pages (a close or slide in flight), so wait
/// briefly before escalating.  Bounded and deadline-aware.
const DECODE_BACKOFFS: [Duration; 3] = [
    Duration::from_micros(500),
    Duration::from_millis(1),
    Duration::from_millis(2),
];

/// Check a decode step's session out of the table and validate
/// everything that must hold before its row may enter a decode batch:
/// the `decode_job` failpoint, shape against the session, the pipelined
/// position guard, a buildable op config, and a well-formed q/k/v view.
/// Any failure checks the entry back in (if it got that far) and
/// returns the same typed error the serial path always produced.
/// Shared by [`run_decode`] and the continuous-batching scheduler's
/// fused-batch admission, so the two paths cannot drift.
pub(crate) fn admit_decode(
    job: &DecodeJob,
    ctx: &EngineCtx,
) -> Result<(SessionEntry, AttentionOp), String> {
    failpoint::hit("decode_job")?;
    let entry = checkout(&ctx.sessions, job.session)?;
    if job.heads != entry.heads || job.d != entry.d {
        let msg = format!(
            "decode shape (h={}, d={}) != session shape (h={}, d={})",
            job.heads, job.d, entry.heads, entry.d
        );
        checkin(&ctx.sessions, job.session, entry);
        return Err(msg);
    }
    // ordering guard: a pipelined step that lands out of order is an
    // explicit error, never a silent mis-ordered cache append
    if let Some(pos) = job.pos {
        let at = entry.cache.len();
        if pos != at {
            let msg = format!(
                "decode step expected position {pos} but session {} is at {at} \
                 (out-of-order pipelined decode?)",
                job.session
            );
            checkin(&ctx.sessions, job.session, entry);
            return Err(msg);
        }
    }
    // typed errors, not expects: these were "validated at open/submit",
    // but a fault between then and now (or a buggy caller bypassing the
    // server) must fail this one ticket, not the worker
    let attn = match entry.cfg.build() {
        Ok(a) => a,
        Err(e) => {
            let msg = format!("session {} config no longer builds: {e}", job.session);
            checkin(&ctx.sessions, job.session, entry);
            return Err(msg);
        }
    };
    if let Err(e) = QkvView::new(job.heads, 1, job.d, &job.q, &job.k, &job.v) {
        let msg = format!("malformed decode job for session {}: {e}", job.session);
        checkin(&ctx.sessions, job.session, entry);
        return Err(msg);
    }
    Ok((entry, attn))
}

/// Run one decode step against its session's checked-out cache.  A
/// decode append can also exhaust the pool (one more page as the window
/// slides); exhaustion walks the full degradation ladder: bounded
/// exponential **backoff** (`retries`), then **LRU-evicting** *other*
/// idle sessions, then — with [`CacheConfig::degrade_window`] set —
/// **degrading** this session once to a tighter sliding window
/// (`degraded_sessions`), and only then **shedding** with an admission
/// reject.
fn run_decode(
    job: &DecodeJob,
    deadline: Option<Instant>,
    ctx: &EngineCtx,
) -> Result<crate::attention::op::DecodeOutput, String> {
    let (mut entry, attn) = admit_decode(job, ctx)?;
    let view = QkvView::new(job.heads, 1, job.d, &job.q, &job.k, &job.v)
        .expect("shape validated by admit_decode");
    let mut backoffs = 0usize;
    let mut evictions = 0usize;
    let res = loop {
        match attn.decode_step(&mut entry.cache, view) {
            Err(e) if e.contains(POOL_EXHAUSTED) => {
                // rung 1: transient — wait for in-flight releases
                if backoffs < DECODE_BACKOFFS.len() {
                    let wait = DECODE_BACKOFFS[backoffs];
                    let fits = match deadline {
                        Some(dl) => Instant::now() + wait < dl,
                        None => true,
                    };
                    if fits {
                        backoffs += 1;
                        ctx.metrics.retries.fetch_add(1, Relaxed);
                        std::thread::sleep(wait);
                        continue;
                    }
                }
                // rung 2: reclaim someone else's idle pages
                if evictions < MAX_ADMISSION_EVICTIONS
                    && evict_lru_session(ctx, Some(job.session))
                {
                    evictions += 1;
                    continue;
                }
                // rung 3: degrade this session (once) and resume
                if let (Some(w), false) = (ctx.cache.degrade_window, entry.degraded) {
                    if entry.cache.degrade(w).is_ok() {
                        entry.degraded = true;
                        ctx.metrics.degraded_sessions.fetch_add(1, Relaxed);
                        continue;
                    }
                }
                // rung 4: shed with explicit backpressure
                break Err(reject_admission(ctx, e));
            }
            other => break other,
        }
    };
    entry.last_used = Instant::now();
    checkin(&ctx.sessions, job.session, entry);
    res
}

/// Run one job on the pure-Rust substrate: one batched multi-head op
/// call over a zero-copy [`QkvView`] of the job buffers (no per-head
/// slicing copies).  Malformed jobs and unbuildable configs fail this
/// job with a typed error instead of panicking the worker.
pub fn execute_substrate(
    job: &AttnJob,
    kind: RouteKind,
    rc: &RouterConfig,
) -> Result<Vec<f32>, String> {
    failpoint::hit("full_job")?;
    let view = QkvView::new(job.heads, job.n, job.d, &job.q, &job.k, &job.v)?;
    let cfg = substrate_config(job, kind, rc);
    let attn = cfg.build()?;
    // serving is forward-only: infer() skips backward-state capture
    Ok(attn.infer(view).into_out())
}

/// Best-effort text of a panic payload (the common `&str` / `String`
/// cases; anything else is reported as opaque).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run one job body with panic isolation: a panic — injected or real —
/// resolves this ticket with an explicit `panic:`-prefixed error
/// instead of killing the worker thread, and bumps `panics_caught`.
/// Callers decide any additional quarantine from the `panic:` marker.
pub(crate) fn catch_job<T>(
    metrics: &Metrics,
    f: impl FnOnce() -> Result<T, String>,
) -> Result<T, String> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            metrics.panics_caught.fetch_add(1, Relaxed);
            Err(format!("panic: {}", panic_message(payload.as_ref())))
        }
    }
}

/// Force-close a session whose job panicked.  The unwind already
/// dropped any checked-out entry (releasing its frames); removing the
/// slot outright means later decodes get an immediate "unknown
/// session" instead of wedging on a checkout that can never succeed.
/// Any entry still in the slot (panic before checkout) is dropped
/// here, returning its pages to the pool.
pub(crate) fn quarantine_session(ctx: &EngineCtx, id: SessionId) {
    let removed = lock_recover(&ctx.sessions).remove(&id);
    drop(removed);
}

/// Spawn the engine.  Returns the submit channel and the PJRT-thread
/// join handle.
///
/// Two execution lanes (§Perf optimization 1, EXPERIMENTS.md): the PJRT
/// lane is a single thread owning the thread-affine [`Runtime`];
/// substrate batches (including all streaming-session work) are
/// forwarded to a small worker pool so they never queue behind artifact
/// compiles (and vice versa).  Head-of-line blocking across lanes
/// dropped p50 queue latency ~8× on the mixed serving workload.
pub fn spawn(
    artifacts_dir: Option<PathBuf>,
    router_config: RouterConfig,
    cache: CacheConfig,
    sched: super::scheduler::SchedConfig,
    metrics: Arc<Metrics>,
    queue_depth: usize,
) -> Result<
    (
        SyncSender<EngineMsg>,
        std::thread::JoinHandle<()>,
        PagePool,
        SessionMap,
        PrefixMap,
    ),
    String,
> {
    let (tx, rx) = std::sync::mpsc::sync_channel::<EngineMsg>(queue_depth);
    let pool = PagePool::with_quant(cache.page_elems, cache.budget_pages, cache.quant);
    let ctx = EngineCtx {
        rc: router_config,
        cache,
        pool: pool.clone(),
        metrics,
        sessions: Arc::new(Mutex::new(HashMap::new())),
        prefixes: Arc::new(Mutex::new(HashMap::new())),
    };
    let sessions = ctx.sessions.clone();
    let prefixes = ctx.prefixes.clone();

    // substrate lane: a shared-receiver worker pool
    let (sub_tx, sub_rx) = std::sync::mpsc::sync_channel::<EngineMsg>(queue_depth);
    let sub_rx = Arc::new(std::sync::Mutex::new(sub_rx));
    let n_workers = 2;
    for w in 0..n_workers {
        let rxw = sub_rx.clone();
        let ctxw = ctx.clone();
        std::thread::Builder::new()
            .name(format!("hyperattn-substrate-{w}"))
            .spawn(move || loop {
                let msg = { lock_recover(&rxw).recv() };
                match msg {
                    Ok(EngineMsg::Batch(batch)) => {
                        for item in batch {
                            execute_one(item, None, &ctxw);
                        }
                    }
                    Ok(EngineMsg::Shutdown) | Err(_) => break,
                }
            })
            .map_err(|e| format!("spawn substrate worker {w}: {e}"))?;
    }

    // decode lane: a single scheduler thread owning the continuous-
    // batching loop.  All `Route::decode_key()` traffic (decode steps,
    // closes, prefix releases, pings) is forwarded here in submission
    // order, so the scheduler's FIFO queue IS the decode lane's
    // ordering guarantee (see `scheduler.rs`).
    let (sched_tx, sched_rx) = std::sync::mpsc::sync_channel::<EngineMsg>(queue_depth);
    let ctxs = ctx.clone();
    let sched_handle = std::thread::Builder::new()
        .name("hyperattn-scheduler".into())
        .spawn(move || super::scheduler::scheduler_loop(sched_rx, ctxs, sched))
        .map_err(|e| format!("spawn scheduler thread: {e}"))?;

    let handle = std::thread::Builder::new()
        .name("hyperattn-engine".into())
        .spawn(move || {
            engine_loop(rx, artifacts_dir, ctx, sub_tx, n_workers, sched_tx, sched_handle)
        })
        .map_err(|e| format!("spawn engine thread: {e}"))?;
    Ok((tx, handle, pool, sessions, prefixes))
}

/// Respond to a flushed item with an explicit shutdown error (instead
/// of silently dropping its oneshot sender).
pub(crate) fn respond_flush(item: WorkItem, metrics: &Metrics) {
    const MSG: &str = "coordinator shutting down; queued work flushed";
    match item.respond {
        Reply::Full(tx) => {
            metrics.jobs_failed.fetch_add(1, Relaxed);
            let _ = tx.send(Err(MSG.into()));
        }
        Reply::Decode(tx) => {
            metrics.jobs_failed.fetch_add(1, Relaxed);
            let _ = tx.send(Err(MSG.into()));
        }
        Reply::Ping(tx) => {
            let _ = tx.send(Err(MSG.into()));
        }
        Reply::None => {}
    }
}

/// Resolve an expired item without executing it (and without touching
/// its session or the pool).  Returns true when the item was consumed.
/// Items with no reply channel (close, prefix release) always run —
/// skipping them would leak sessions or pinned pages — and pings
/// always answer (an expired liveness probe is still a liveness probe).
///
/// Overload-accounting contract: the expired request's **queued time
/// is recorded** into `queue_latency` and `e2e_latency` (exec = 0)
/// before the ticket resolves.  Shed and expired requests are exactly
/// the ones that dominate the tail under overload; dropping them from
/// the histograms made p99 *understate* precisely when the system was
/// saturated.  The `deadline_expired` counter is surfaced beside the
/// latency lines in [`Metrics::report`].
pub(crate) fn expire_if_late(item: WorkItem, metrics: &Metrics) -> Option<WorkItem> {
    let late = match (item.deadline, &item.respond) {
        (Some(dl), Reply::Full(_) | Reply::Decode(_)) => Instant::now() >= dl,
        _ => false,
    };
    if !late {
        return Some(item);
    }
    let queued = item.submitted.elapsed();
    let queue_us = queued.as_micros() as u64;
    metrics.queue_latency.record(queue_us);
    metrics.e2e_latency.record(queue_us);
    metrics.deadline_expired.fetch_add(1, Relaxed);
    metrics.jobs_failed.fetch_add(1, Relaxed);
    let msg = format!("{DEADLINE_EXPIRED} (queued {queued:?})");
    match item.respond {
        Reply::Full(tx) => {
            let _ = tx.send(Err(msg));
        }
        Reply::Decode(tx) => {
            let _ = tx.send(Err(msg));
        }
        Reply::Ping(_) | Reply::None => unreachable!("filtered above"),
    }
    None
}

/// Execute one work item (on whichever lane) and respond.
pub(crate) fn execute_one(item: WorkItem, runtime: Option<&Runtime>, ctx: &EngineCtx) {
    let rc = &ctx.rc;
    let metrics = &*ctx.metrics;
    let sessions = &ctx.sessions;
    let Some(item) = expire_if_late(item, metrics) else { return };
    let WorkItem { work, route, submitted, respond, deadline } = item;
    let queue_us = submitted.elapsed().as_micros() as u64;
    let exec_start = Instant::now();

    match work {
        Work::Full(job) => {
            let result = catch_job(metrics, || match (&route.artifact, runtime) {
                (Some(name), Some(rt)) => {
                    let seed = matches!(route.kind, RouteKind::Hyper).then_some(job.seed);
                    match rt.run_attention(
                        name, job.heads, job.n, job.d, &job.q, &job.k, &job.v, seed,
                    ) {
                        Ok(out) => Ok((out, Backend::Artifact(name.clone()))),
                        Err(e) => {
                            // artifact failure degrades to substrate
                            eprintln!(
                                "engine: artifact {name} failed ({e:#}); substrate fallback"
                            );
                            execute_substrate(&job, route.kind, rc)
                                .map(|out| (out, Backend::Substrate))
                        }
                    }
                }
                _ => execute_substrate(&job, route.kind, rc).map(|out| (out, Backend::Substrate)),
            });

            let exec_us = exec_start.elapsed().as_micros() as u64;
            metrics.queue_latency.record(queue_us);
            metrics.exec_latency.record(exec_us);
            metrics.e2e_latency.record(queue_us + exec_us);
            let response = match result {
                Ok((out, backend)) => {
                    match backend {
                        Backend::Artifact(_) => {
                            metrics.artifact_jobs.fetch_add(1, Relaxed);
                        }
                        Backend::Substrate => {
                            metrics.substrate_jobs.fetch_add(1, Relaxed);
                        }
                    }
                    metrics.jobs_completed.fetch_add(1, Relaxed);
                    Ok(AttnResponse { id: job.id, out, backend, queue_us, exec_us })
                }
                Err(e) => {
                    metrics.jobs_failed.fetch_add(1, Relaxed);
                    Err(e)
                }
            };
            if let Reply::Full(tx) = respond {
                let _ = tx.send(response);
            }
        }
        Work::Open { session, job, prefix } => {
            // prefill the prompt into a fresh cache on the substrate
            // (streaming sessions are shape-dynamic: no artifact lane);
            // with a prefix key, fork the pinned cache instead
            let result = catch_job(metrics, || {
                run_open(session, &job, prefix.as_deref(), route.kind, ctx)
            });
            if matches!(&result, Err(e) if e.starts_with("panic:")) {
                // a panicked open may have left a half-registered slot
                quarantine_session(ctx, session);
            }
            let exec_us = exec_start.elapsed().as_micros() as u64;
            metrics.queue_latency.record(queue_us);
            metrics.exec_latency.record(exec_us);
            metrics.e2e_latency.record(queue_us + exec_us);
            metrics.substrate_jobs.fetch_add(1, Relaxed);
            match &result {
                Ok(_) => {
                    metrics.sessions_opened.fetch_add(1, Relaxed);
                    metrics.jobs_completed.fetch_add(1, Relaxed);
                }
                Err(_) => {
                    metrics.jobs_failed.fetch_add(1, Relaxed);
                }
            }
            if let Reply::Full(tx) = respond {
                let _ = tx.send(result.map(|out| AttnResponse {
                    id: job.id,
                    out,
                    backend: Backend::Substrate,
                    queue_us,
                    exec_us,
                }));
            }
        }
        Work::Decode(job) => {
            let result = catch_job(metrics, || run_decode(&job, deadline, ctx));
            if matches!(&result, Err(e) if e.starts_with("panic:")) {
                // the unwind dropped the checked-out cache (frames are
                // already back in the pool); removing the slot keeps
                // later steps from wedging on an impossible checkout
                quarantine_session(ctx, job.session);
            }
            let exec_us = exec_start.elapsed().as_micros() as u64;
            metrics.queue_latency.record(queue_us);
            metrics.decode_latency.record(exec_us);
            // decode steps count toward e2e too — shed steps (rung 4 of
            // the exhaustion ladder) resolve through this same arm, so
            // overload tail latency lands in the histogram instead of
            // silently vanishing with the error string
            metrics.e2e_latency.record(queue_us + exec_us);
            match &result {
                Ok(_) => {
                    metrics.decode_steps.fetch_add(1, Relaxed);
                    metrics.jobs_completed.fetch_add(1, Relaxed);
                }
                Err(_) => {
                    metrics.jobs_failed.fetch_add(1, Relaxed);
                }
            }
            if let Reply::Decode(tx) = respond {
                let _ = tx.send(result.map(|o| DecodeResponse {
                    session: job.session,
                    pos: o.pos,
                    out: o.out,
                    sampled: o.sampled,
                    queue_us,
                    exec_us,
                }));
            }
        }
        Work::Close { session } => {
            let _ = catch_job(metrics, || {
                close_session(sessions, session);
                Ok(())
            });
            metrics.sessions_closed.fetch_add(1, Relaxed);
        }
        Work::RegisterPrefix { key, seq, job } => {
            let result =
                catch_job(metrics, || run_register_prefix(&key, seq, &job, route.kind, ctx));
            let exec_us = exec_start.elapsed().as_micros() as u64;
            metrics.queue_latency.record(queue_us);
            metrics.exec_latency.record(exec_us);
            metrics.substrate_jobs.fetch_add(1, Relaxed);
            match &result {
                Ok(_) => {
                    metrics.jobs_completed.fetch_add(1, Relaxed);
                }
                Err(_) => {
                    metrics.jobs_failed.fetch_add(1, Relaxed);
                }
            }
            if let Reply::Full(tx) = respond {
                let _ = tx.send(result.map(|out| AttnResponse {
                    id: job.id,
                    out,
                    backend: Backend::Substrate,
                    queue_us,
                    exec_us,
                }));
            }
        }
        Work::ReleasePrefix { key, seq } => {
            // unpinning only drops the registry's handles; pages still
            // shared by live forked sessions stay resident with them.
            // A panicked release is retried as a tombstone so the key
            // cannot stay pinned forever.
            let seq_retry = seq;
            let key_retry = key.clone();
            if catch_job(metrics, || {
                run_release_prefix(key, seq, ctx);
                Ok(())
            })
            .is_err()
            {
                let mut map = lock_recover(&ctx.prefixes);
                let newer = match map.get(&key_retry) {
                    Some(PrefixSlot::Live(e)) => e.seq > seq_retry,
                    Some(PrefixSlot::Released(s)) => *s >= seq_retry,
                    None => false,
                };
                if !newer {
                    map.insert(key_retry, PrefixSlot::Released(seq_retry));
                }
            }
        }
        Work::Ping => {
            if let Reply::Ping(tx) = respond {
                let _ = tx.send(Ok(()));
            }
        }
    }
}

fn engine_loop(
    rx: Receiver<EngineMsg>,
    artifacts_dir: Option<PathBuf>,
    ctx: EngineCtx,
    sub_tx: SyncSender<EngineMsg>,
    n_workers: usize,
    sched_tx: SyncSender<EngineMsg>,
    sched_handle: std::thread::JoinHandle<()>,
) {
    // Runtime is created lazily on this thread (PjRtClient is !Send).
    let runtime: Option<Runtime> = artifacts_dir.and_then(|dir| match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("engine: failed to open artifacts at {dir:?}: {e:#}; substrate only");
            None
        }
    });

    // idle-session sweep cadence: ~ttl/4, floored so a tiny ttl cannot
    // turn the engine thread into a spin loop
    let sweep_every = ctx
        .cache
        .idle_ttl
        .map(|ttl| (ttl / 4).max(Duration::from_millis(10)));
    let mut last_sweep = Instant::now();

    loop {
        let msg = match sweep_every {
            Some(interval) => match rx.recv_timeout(interval) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            },
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
        };
        // sweep on idle timeouts AND between messages under sustained
        // traffic — a busy engine must still reclaim leaked sessions
        if let (Some(interval), Some(ttl)) = (sweep_every, ctx.cache.idle_ttl) {
            if last_sweep.elapsed() >= interval {
                sweep_idle(&ctx, ttl);
                last_sweep = Instant::now();
            }
        }
        let Some(msg) = msg else { continue };
        // chaos knob for queue-latency pressure; only `delay` actions
        // apply here (a panic would kill the engine thread, not a job)
        failpoint::delay_only("engine_recv");
        let batch = match msg {
            EngineMsg::Batch(b) => b,
            EngineMsg::Shutdown => {
                // flush anything still queued behind the shutdown with
                // an explicit error response — in-flight streaming
                // sessions must not leak their oneshot senders
                while let Ok(m) = rx.try_recv() {
                    if let EngineMsg::Batch(batch) = m {
                        for item in batch {
                            respond_flush(item, &ctx.metrics);
                        }
                    }
                }
                break;
            }
        };
        ctx.metrics.record_batch(batch.len());
        // route the whole batch to its lane (batch keys are per-route, so
        // a batch is uniformly artifact, decode-lane, or substrate)
        let is_artifact = batch
            .first()
            .map(|i| i.route.artifact.is_some() && runtime.is_some())
            .unwrap_or(false);
        let is_decode_lane = batch.first().map(|i| i.route.decode).unwrap_or(false);
        if is_artifact {
            for item in batch {
                execute_one(item, runtime.as_ref(), &ctx);
            }
        } else if is_decode_lane {
            // the continuous-batching scheduler owns the decode lane:
            // forwarding in receive order preserves the FIFO barrier
            // (pings resolve only after the steps submitted before
            // them).  If the scheduler is gone, degrade to inline
            // session-serial execution rather than dropping tickets.
            if let Err(e) = sched_tx.send(EngineMsg::Batch(batch)) {
                if let EngineMsg::Batch(batch) = e.0 {
                    for item in batch {
                        execute_one(item, None, &ctx);
                    }
                }
            }
        } else {
            // forward to the substrate pool; if it is gone, run inline
            if let Err(e) = sub_tx.send(EngineMsg::Batch(batch)) {
                if let EngineMsg::Batch(batch) = e.0 {
                    for item in batch {
                        execute_one(item, None, &ctx);
                    }
                }
            }
        }
    }
    // stop the scheduler first and JOIN it before tearing the session
    // table down: the scheduler's draft lanes hold forked caches whose
    // COW pages must return to the pool before shutdown completes (the
    // pool-conservation invariant the chaos harness asserts), and its
    // queued tickets must be flushed before their senders vanish.
    let _ = sched_tx.send(EngineMsg::Shutdown);
    let _ = sched_handle.join();
    for _ in 0..n_workers {
        let _ = sub_tx.send(EngineMsg::Shutdown);
    }
    // any caches still live are dropped here, returning their pages to
    // the pool; a worker holding a checked-out entry simply drops it at
    // checkin.  Pinned prefixes release their handles the same way.
    lock_recover(&ctx.sessions).clear();
    lock_recover(&ctx.prefixes).clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::coordinator::request::ModePreference;
    use crate::linalg::MatRef;
    use crate::rng::Rng;

    fn job(n: usize, causal: bool, seed: i32) -> AttnJob {
        let (h, d) = (2, 16);
        let mut rng = Rng::new(seed as u64);
        AttnJob {
            id: 9,
            heads: h,
            n,
            d,
            q: rng.normal_vec(h * n * d),
            k: rng.normal_vec(h * n * d),
            v: rng.normal_vec(h * n * d),
            causal,
            mode: ModePreference::Auto,
            seed,
        }
    }

    #[test]
    fn pick_block_divides() {
        assert_eq!(pick_block(128, 32), 32);
        assert_eq!(pick_block(96, 64), 48);
        assert_eq!(pick_block(97, 64), 1); // prime
        assert_eq!(pick_block(4, 64), 4);
        // O(√n) divisor enumeration: prime / power-of-two / odd composite
        assert_eq!(pick_block(1009, 256), 1); // prime
        assert_eq!(pick_block(1 << 14, 256), 256); // power of two
        assert_eq!(pick_block(3 * 5 * 7 * 11, 100), 77); // odd composite
        assert_eq!(pick_block(225, 100), 75); // odd composite square
    }

    #[test]
    fn substrate_exact_matches_reference() {
        let j = job(48, false, 3);
        let rc = RouterConfig::default();
        let out = execute_substrate(&j, RouteKind::Exact, &rc).unwrap();
        // head 0 vs naive, through zero-copy views of the job buffers
        let per = 48 * 16;
        let m = |x: &[f32]| MatRef::new(48, 16, &x[..per]).to_mat();
        let exact = exact::naive_attention(&m(&j.q), &m(&j.k), &m(&j.v), false, None);
        let got = MatRef::new(48, 16, &out[..per]).to_mat();
        assert!(exact.max_abs_diff(&got) < 1e-5);
    }

    #[test]
    fn substrate_hyper_runs_all_shapes() {
        let rc = RouterConfig { block: 16, samples: 16, causal_base: 32, ..Default::default() };
        for n in [16usize, 48, 97, 128] {
            for causal in [false, true] {
                let j = job(n, causal, 1);
                let out = execute_substrate(&j, RouteKind::Hyper, &rc).unwrap();
                assert_eq!(out.len(), 2 * n * 16);
                assert!(out.iter().all(|x| x.is_finite()), "n={n} causal={causal}");
            }
        }
    }

    #[test]
    fn substrate_deterministic() {
        let rc = RouterConfig { block: 16, samples: 16, ..Default::default() };
        let j = job(64, false, 5);
        let a = execute_substrate(&j, RouteKind::Hyper, &rc).unwrap();
        let b = execute_substrate(&j, RouteKind::Hyper, &rc).unwrap();
        assert_eq!(a, b);
    }

    /// The explicit-hyper prime-n guard that used to live here as an
    /// `if block < 8` now comes from the op's AutoPolicy — same result.
    #[test]
    fn substrate_prime_n_hyper_degrades_to_exact() {
        let rc = RouterConfig { block: 256, samples: 16, ..Default::default() };
        let j = job(97, false, 2);
        let out = execute_substrate(&j, RouteKind::Hyper, &rc).unwrap();
        let per = 97 * 16;
        let m = |x: &[f32]| MatRef::new(97, 16, &x[..per]).to_mat();
        let exact = exact::naive_attention(&m(&j.q), &m(&j.k), &m(&j.v), false, None);
        let got = MatRef::new(97, 16, &out[..per]).to_mat();
        assert!(exact.max_abs_diff(&got) < 1e-5, "prime n must run exact");
    }

    fn entry(heads: usize, d: usize) -> SessionEntry {
        SessionEntry {
            cfg: AttnConfig::flash(true),
            heads,
            d,
            cache: AttnCache::new(heads, d),
            last_used: Instant::now(),
            degraded: false,
        }
    }

    fn test_ctx() -> EngineCtx {
        EngineCtx {
            rc: RouterConfig::default(),
            cache: CacheConfig::default(),
            pool: PagePool::unbounded(CacheConfig::default().page_elems),
            metrics: Arc::new(Metrics::new()),
            sessions: Arc::new(Mutex::new(HashMap::new())),
            prefixes: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Session checkout/checkin/close protocol on the raw table.
    #[test]
    fn session_table_checkout_protocol() {
        let sessions: SessionMap = Arc::new(Mutex::new(HashMap::new()));
        assert!(checkout(&sessions, 1).is_err(), "unknown session");
        sessions.lock().unwrap().insert(1, Some(entry(2, 8)));
        let e = checkout(&sessions, 1).unwrap();
        // while checked out the slot is empty but present
        assert!(matches!(sessions.lock().unwrap().get(&1), Some(None)));
        checkin(&sessions, 1, e);
        assert!(matches!(sessions.lock().unwrap().get(&1), Some(Some(_))));
        close_session(&sessions, 1);
        assert!(sessions.lock().unwrap().get(&1).is_none());
        // closing again is a no-op
        close_session(&sessions, 1);
        // checkin after close drops the entry silently
        sessions.lock().unwrap().insert(2, Some(entry(2, 8)));
        let e2 = checkout(&sessions, 2).unwrap();
        sessions.lock().unwrap().remove(&2);
        checkin(&sessions, 2, e2);
        assert!(sessions.lock().unwrap().get(&2).is_none());
    }

    /// LRU eviction picks the stalest idle session, skips checked-out
    /// sessions and the requester, and reports when nothing is
    /// evictable.
    #[test]
    fn lru_eviction_order_and_skips() {
        let ctx = test_ctx();
        assert!(!evict_lru_session(&ctx, None), "empty table: nothing to evict");
        let old = Instant::now() - Duration::from_secs(60);
        {
            let mut map = ctx.sessions.lock().unwrap();
            let mut stale = entry(1, 8);
            stale.last_used = old;
            map.insert(1, Some(stale));
            map.insert(2, Some(entry(1, 8)));
            map.insert(3, None); // checked out: never a victim
        }
        assert!(evict_lru_session(&ctx, None));
        {
            let map = ctx.sessions.lock().unwrap();
            assert!(map.get(&1).is_none(), "stalest session must go first");
            assert!(map.get(&2).is_some());
            assert!(matches!(map.get(&3), Some(None)));
        }
        // the requester itself is skipped even when stalest
        assert!(!evict_lru_session(&ctx, Some(2)), "only candidate is skipped");
        assert!(evict_lru_session(&ctx, None));
        assert_eq!(
            ctx.metrics.sessions_evicted.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
        // only a checked-out slot left: nothing evictable
        assert!(!evict_lru_session(&ctx, None));
    }

    /// The TTL sweep reclaims idle sessions (the leaked-handle fix),
    /// frees their pages, and leaves fresh/checked-out sessions alone.
    #[test]
    fn ttl_sweep_reclaims_idle_sessions() {
        let ctx = test_ctx();
        let mut rng = Rng::new(7);
        // a session with real pages, stale for a minute
        let mut stale = SessionEntry {
            cfg: AttnConfig::flash(true),
            heads: 1,
            d: 8,
            cache: AttnCache::with_pool(1, 8, op::CachePolicy::Full, &ctx.pool).unwrap(),
            last_used: Instant::now() - Duration::from_secs(60),
            degraded: false,
        };
        let buf = rng.normal_vec(8 * 4);
        let view = QkvView::new(1, 4, 8, &buf, &buf, &buf).unwrap();
        stale.cache.append_kv(&view).unwrap();
        assert!(ctx.pool.stats().outstanding > 0);
        {
            let mut map = ctx.sessions.lock().unwrap();
            map.insert(1, Some(stale));
            map.insert(2, Some(entry(1, 8))); // fresh
            map.insert(3, None); // checked out
        }
        sweep_idle(&ctx, Duration::from_secs(30));
        {
            let map = ctx.sessions.lock().unwrap();
            assert!(map.get(&1).is_none(), "idle session must be reclaimed");
            assert!(map.get(&2).is_some());
            assert!(matches!(map.get(&3), Some(None)));
        }
        assert_eq!(
            ctx.metrics.sessions_reclaimed.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // its pages went back to the pool
        assert_eq!(ctx.pool.stats().outstanding, 0);
        let g = cache_gauges(&ctx.sessions, &ctx.prefixes, &ctx.pool, &ctx.metrics);
        assert_eq!(g.sessions_reclaimed, 1);
        assert_eq!(g.per_session.len(), 2);
    }

    /// The prefix registry on the raw engine context: registering pins
    /// a cache, forked opens charge only the private tail, and the
    /// gauges count shared pages once.
    #[test]
    fn prefix_fork_open_shares_pages() {
        let mut ctx = test_ctx();
        // (h=2, d=16) -> 8 rows per page under this page_elems
        ctx.cache.page_elems = 3 * 2 * 16 * 8;
        ctx.pool = PagePool::unbounded(ctx.cache.page_elems);
        let prefix_job = job(20, true, 1); // 20 rows: 2 full pages + 4-row tail
        run_register_prefix("sys", 1, &prefix_job, RouteKind::Exact, &ctx).unwrap();
        let after_prefix = ctx.pool.stats().outstanding;
        assert_eq!(after_prefix, 3);
        // two sessions fork it with 2-row suffixes
        let suffix = job(2, true, 2);
        run_open(1, &suffix, Some("sys"), RouteKind::Exact, &ctx).unwrap();
        run_open(2, &suffix, Some("sys"), RouteKind::Exact, &ctx).unwrap();
        let s = ctx.pool.stats();
        // prefix 3 pages + one COW'd tail page per session
        assert_eq!(s.outstanding, 5, "shared pages charged once");
        assert_eq!(s.cows, 2);
        assert_eq!(s.shared, 2, "the two frozen prefix pages");
        let g = cache_gauges(&ctx.sessions, &ctx.prefixes, &ctx.pool, &ctx.metrics);
        assert_eq!(g.pages_shared, 2);
        assert_eq!(g.cow_copies, 2);
        assert_eq!(g.per_prefix.len(), 1);
        assert_eq!(g.per_prefix[0].0, "sys");
        assert_eq!(g.per_session.len(), 2);
        // sessions see prefix + suffix rows
        {
            let map = ctx.sessions.lock().unwrap();
            for slot in map.values() {
                assert_eq!(slot.as_ref().unwrap().cache.len(), 22);
            }
        }
        // unknown / shape-mismatched prefixes are rejected loudly
        assert!(run_open(3, &suffix, Some("nope"), RouteKind::Exact, &ctx)
            .unwrap_err()
            .contains("unknown prefix"));
        // releasing the prefix frees only the unshared tail page; the
        // frozen pages live on with the sessions
        run_release_prefix("sys".into(), 2, &ctx);
        let s = ctx.pool.stats();
        assert_eq!(s.outstanding, 4, "prefix tail freed, shared pages survive");
        assert_eq!(s.shared, 2);
        // dropping the sessions frees everything
        ctx.sessions.lock().unwrap().clear();
        assert_eq!(ctx.pool.stats().outstanding, 0);
    }

    /// The register/release reordering guard: a release that executes
    /// BEFORE its register (cross-lane batch reordering) leaves a
    /// tombstone the older register must not overwrite — no permanently
    /// pinned pages — while a later register reclaims the key.
    #[test]
    fn prefix_release_overtaking_register_leaves_no_pin() {
        let mut ctx = test_ctx();
        ctx.cache.page_elems = 3 * 2 * 16 * 8;
        ctx.pool = PagePool::unbounded(ctx.cache.page_elems);
        let pjob = job(20, true, 1);
        // client submitted register (seq 1) then release (seq 2), but
        // the release executed first
        run_release_prefix("sys".into(), 2, &ctx);
        run_register_prefix("sys", 1, &pjob, RouteKind::Exact, &ctx).unwrap();
        assert_eq!(
            ctx.pool.stats().outstanding,
            0,
            "the superseded register must not pin pages"
        );
        assert!(
            run_open(1, &job(2, true, 3), Some("sys"), RouteKind::Exact, &ctx).is_err(),
            "tombstoned prefix is not forkable"
        );
        let g = cache_gauges(&ctx.sessions, &ctx.prefixes, &ctx.pool, &ctx.metrics);
        assert!(g.per_prefix.is_empty(), "tombstones are not reported as live");
        // a NEWER register (seq 3) reclaims the key
        run_register_prefix("sys", 3, &pjob, RouteKind::Exact, &ctx).unwrap();
        assert_eq!(ctx.pool.stats().outstanding, 3);
        run_open(2, &job(2, true, 4), Some("sys"), RouteKind::Exact, &ctx).unwrap();
        // and a stale release (seq older than the live register) is a no-op
        run_release_prefix("sys".into(), 2, &ctx);
        assert_eq!(
            cache_gauges(&ctx.sessions, &ctx.prefixes, &ctx.pool, &ctx.metrics)
                .per_prefix
                .len(),
            1,
            "stale release must not unpin a newer register"
        );
    }

    fn decode_job(session: SessionId, seed: u64) -> DecodeJob {
        let (h, d) = (2, 16);
        let mut rng = Rng::new(seed);
        DecodeJob {
            session,
            heads: h,
            d,
            pos: None,
            q: rng.normal_vec(h * d),
            k: rng.normal_vec(h * d),
            v: rng.normal_vec(h * d),
        }
    }

    /// The decode overload ladder end to end on a raw context: a full
    /// budget first backs off (counted retries), finds nothing to
    /// LRU-evict, **degrades** the session to the configured window
    /// (freeing its own pages), and resumes decoding — then, with
    /// degradation disabled, the same pressure sheds with an explicit
    /// admission reject.
    #[test]
    fn decode_ladder_backoff_degrade_shed() {
        let run = |degrade_window: Option<usize>| {
            let mut ctx = test_ctx();
            // (h=2, d=16) -> 4 rows per page; budget 4 pages = 16 rows
            ctx.cache.page_elems = 3 * 2 * 16 * 4;
            ctx.cache.budget_pages = Some(4);
            ctx.cache.degrade_window = degrade_window;
            ctx.pool = PagePool::new(ctx.cache.page_elems, Some(4));
            // the prompt fills the budget exactly
            run_open(1, &job(16, true, 1), None, RouteKind::Exact, &ctx).unwrap();
            assert_eq!(ctx.pool.stats().outstanding, 4);
            (run_decode(&decode_job(1, 2), None, &ctx), ctx)
        };
        // ladder reaches the degrade rung and the step succeeds
        let (res, ctx) = run(Some(8));
        res.unwrap();
        assert_eq!(ctx.metrics.retries.load(Relaxed), 3, "all three backoffs first");
        assert_eq!(ctx.metrics.degraded_sessions.load(Relaxed), 1);
        assert_eq!(ctx.metrics.admission_rejects.load(Relaxed), 0);
        {
            let map = ctx.sessions.lock().unwrap();
            let e = map.get(&1).unwrap().as_ref().unwrap();
            assert!(e.degraded);
            assert!(matches!(e.cache.policy(), CachePolicy::SlidingWindow { .. }));
        }
        let g = cache_gauges(&ctx.sessions, &ctx.prefixes, &ctx.pool, &ctx.metrics);
        assert_eq!(g.degraded_sessions, 1);
        // a later step under the (now windowed) session keeps serving:
        // the slide recycles its own pages
        run_decode(&decode_job(1, 3), None, &ctx).unwrap();
        assert_eq!(ctx.metrics.degraded_sessions.load(Relaxed), 1, "degrade fires once");
        // without a degrade window the same pressure sheds explicitly
        let (res, ctx) = run(None);
        let err = res.unwrap_err();
        assert!(err.contains("admission rejected"), "{err}");
        assert!(err.contains(POOL_EXHAUSTED), "{err}");
        assert_eq!(ctx.metrics.admission_rejects.load(Relaxed), 1);
        assert_eq!(ctx.metrics.degraded_sessions.load(Relaxed), 0);
        // the failed step did not grow the cache and the session is
        // intact (shed is not a close)
        let map = ctx.sessions.lock().unwrap();
        assert_eq!(map.get(&1).unwrap().as_ref().unwrap().cache.len(), 16);
    }

    /// Panic isolation: an injected decode panic resolves as an
    /// explicit `panic:` error, quarantines only that session (frames
    /// released), and the engine context keeps serving other sessions.
    #[test]
    fn panicking_decode_quarantines_session_only() {
        let _g = failpoint::test_lock::serial();
        let mut ctx = test_ctx();
        ctx.cache.page_elems = 3 * 2 * 16 * 4;
        ctx.pool = PagePool::unbounded(ctx.cache.page_elems);
        run_open(1, &job(8, true, 1), None, RouteKind::Exact, &ctx).unwrap();
        run_open(2, &job(8, true, 2), None, RouteKind::Exact, &ctx).unwrap();
        let pages_before = ctx.pool.stats().outstanding;
        assert!(pages_before > 0);
        failpoint::configure("decode_job=panic", 0).unwrap();
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        execute_one(
            WorkItem {
                work: Work::Decode(decode_job(1, 3)),
                route: Route::decode_key(),
                submitted: Instant::now(),
                deadline: None,
                respond: Reply::Decode(tx),
            },
            None,
            &ctx,
        );
        failpoint::clear();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.starts_with("panic:"), "{err}");
        assert!(err.contains(failpoint::INJECTED), "{err}");
        assert_eq!(ctx.metrics.panics_caught.load(Relaxed), 1);
        {
            let map = ctx.sessions.lock().unwrap();
            assert!(map.get(&1).is_none(), "panicking session is quarantined");
            assert!(map.get(&2).is_some(), "other sessions untouched");
        }
        // the quarantined session's frames went back to the pool
        let s = ctx.pool.stats();
        assert_eq!(s.outstanding + s.free, (s.allocs - s.reuses) as usize);
        assert!(s.outstanding < pages_before);
        // a retry on the dead id errors immediately (no 10s wedge) and
        // the healthy session still decodes
        let t0 = Instant::now();
        assert!(run_decode(&decode_job(1, 4), None, &ctx)
            .unwrap_err()
            .contains("unknown session"));
        assert!(t0.elapsed() < Duration::from_secs(1));
        run_decode(&decode_job(2, 5), None, &ctx).unwrap();
    }

    /// An expired deadline resolves the ticket with
    /// [`DEADLINE_EXPIRED`] before any session or pool work; close
    /// items always run regardless.
    #[test]
    fn expired_deadline_resolves_before_work() {
        let ctx = test_ctx();
        run_open(1, &job(8, true, 1), None, RouteKind::Exact, &ctx).unwrap();
        let steps_before = ctx.metrics.decode_steps.load(Relaxed);
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        execute_one(
            WorkItem {
                work: Work::Decode(decode_job(1, 2)),
                route: Route::decode_key(),
                submitted: Instant::now(),
                deadline: Some(Instant::now() - Duration::from_millis(1)),
                respond: Reply::Decode(tx),
            },
            None,
            &ctx,
        );
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains(DEADLINE_EXPIRED), "{err}");
        assert_eq!(ctx.metrics.deadline_expired.load(Relaxed), 1);
        assert_eq!(ctx.metrics.decode_steps.load(Relaxed), steps_before, "no work ran");
        assert_eq!(
            ctx.sessions.lock().unwrap().get(&1).unwrap().as_ref().unwrap().cache.len(),
            8,
            "expired step must not touch the cache"
        );
        // a close with an absurd deadline still executes
        execute_one(
            WorkItem {
                work: Work::Close { session: 1 },
                route: Route::decode_key(),
                submitted: Instant::now(),
                deadline: Some(Instant::now() - Duration::from_secs(5)),
                respond: Reply::None,
            },
            None,
            &ctx,
        );
        assert!(ctx.sessions.lock().unwrap().is_empty(), "close is deadline-exempt");
        // a fresh (unexpired) deadline executes normally
        run_open(3, &job(8, true, 3), None, RouteKind::Exact, &ctx).unwrap();
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        execute_one(
            WorkItem {
                work: Work::Decode(decode_job(3, 4)),
                route: Route::decode_key(),
                submitted: Instant::now(),
                deadline: Some(Instant::now() + Duration::from_secs(30)),
                respond: Reply::Decode(tx),
            },
            None,
            &ctx,
        );
        rx.recv().unwrap().unwrap();
    }
}

//! Execution engine: a dedicated OS thread that owns the thread-affine
//! PJRT [`Runtime`] and drains batches from the batcher.
//!
//! Jobs routed to an artifact run on PJRT; everything else runs on the
//! pure-Rust substrate through the unified
//! [`crate::attention::op::AttentionOp`] API (internally parallel over
//! heads and tiles via the [`crate::par`] fork/join pool — this tree is
//! rayon-free — so a single engine thread still saturates the machine).

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::metrics::Metrics;
use super::request::{AttnJob, AttnResponse, Backend};
use super::router::{Route, RouteKind, RouterConfig};
use crate::attention::op::{self, AttnConfig, SeedPolicy};
use crate::linalg::QkvView;
use crate::runtime::Runtime;

/// One job in flight, with its response channel (bounded-1 std channel
/// acting as a oneshot).
pub struct WorkItem {
    pub job: AttnJob,
    pub route: Route,
    pub submitted: Instant,
    pub respond: SyncSender<Result<AttnResponse, String>>,
}

/// Messages to the engine thread.
pub enum EngineMsg {
    Batch(Vec<WorkItem>),
    Shutdown,
}

/// Largest block size ≤ `target` that divides n (≥ 1).  Delegates to
/// the O(√n) divisor enumeration in [`crate::attention::op::fit_block`]
/// (the old downward scan here was O(n) per job for prime n).
pub fn pick_block(n: usize, target: usize) -> usize {
    op::fit_block(n, target)
}

/// The substrate [`AttnConfig`] for one routed job: the route's
/// algorithm choice plus the router's block/sample/base targets.  All
/// shape fitting (divisor blocks, prime-n exact fallback, causal
/// dispatch) happens inside the op's documented policy.
pub fn substrate_config(job: &AttnJob, kind: RouteKind, rc: &RouterConfig) -> AttnConfig {
    let backend = match (kind, job.causal) {
        (RouteKind::Exact, _) => op::Backend::Flash,
        (RouteKind::Hyper, false) => op::Backend::Hyper,
        (RouteKind::Hyper, true) => op::Backend::CausalHyper,
    };
    AttnConfig {
        backend,
        causal: job.causal,
        block: rc.block.max(1),
        samples: rc.samples,
        causal_base: rc.causal_base,
        seed: SeedPolicy::PerHead(job.seed as u64),
        // the router's policy carries through to the op, so the
        // degenerate-block guard and any threshold tuning share one
        // source of truth
        auto: rc.auto_policy(),
        ..Default::default()
    }
}

/// Run one job on the pure-Rust substrate: one batched multi-head op
/// call over a zero-copy [`QkvView`] of the job buffers (no per-head
/// slicing copies).
pub fn execute_substrate(job: &AttnJob, kind: RouteKind, rc: &RouterConfig) -> Vec<f32> {
    let view = QkvView::new(job.heads, job.n, job.d, &job.q, &job.k, &job.v)
        .expect("job validated at submit");
    let cfg = substrate_config(job, kind, rc);
    let attn = cfg.build().expect("substrate config is valid by construction");
    // serving is forward-only: infer() skips backward-state capture
    attn.infer(view).into_out()
}

/// Spawn the engine.  Returns the submit channel and the PJRT-thread
/// join handle.
///
/// Two execution lanes (§Perf optimization 1, EXPERIMENTS.md): the PJRT
/// lane is a single thread owning the thread-affine [`Runtime`];
/// substrate batches are forwarded to a small worker pool so they never
/// queue behind artifact compiles (and vice versa).  Head-of-line
/// blocking across lanes dropped p50 queue latency ~8× on the mixed
/// serving workload.
pub fn spawn(
    artifacts_dir: Option<PathBuf>,
    router_config: RouterConfig,
    metrics: Arc<Metrics>,
    queue_depth: usize,
) -> (SyncSender<EngineMsg>, std::thread::JoinHandle<()>) {
    let (tx, rx) = std::sync::mpsc::sync_channel::<EngineMsg>(queue_depth);

    // substrate lane: a shared-receiver worker pool
    let (sub_tx, sub_rx) = std::sync::mpsc::sync_channel::<EngineMsg>(queue_depth);
    let sub_rx = Arc::new(std::sync::Mutex::new(sub_rx));
    let n_workers = 2;
    for w in 0..n_workers {
        let rxw = sub_rx.clone();
        let rc = router_config.clone();
        let m = metrics.clone();
        std::thread::Builder::new()
            .name(format!("hyperattn-substrate-{w}"))
            .spawn(move || loop {
                let msg = { rxw.lock().unwrap().recv() };
                match msg {
                    Ok(EngineMsg::Batch(batch)) => {
                        for item in batch {
                            execute_one(item, None, &rc, &m);
                        }
                    }
                    Ok(EngineMsg::Shutdown) | Err(_) => break,
                }
            })
            .expect("spawn substrate worker");
    }

    let handle = std::thread::Builder::new()
        .name("hyperattn-engine".into())
        .spawn(move || {
            engine_loop(rx, artifacts_dir, router_config, metrics, sub_tx, n_workers)
        })
        .expect("spawn engine thread");
    (tx, handle)
}

/// Execute one work item (on whichever lane) and respond.
fn execute_one(
    item: WorkItem,
    runtime: Option<&Runtime>,
    rc: &RouterConfig,
    metrics: &Metrics,
) {
    let WorkItem { job, route, submitted, respond } = item;
    let queue_us = submitted.elapsed().as_micros() as u64;
    let exec_start = Instant::now();

    let (result, backend) = match (&route.artifact, runtime) {
        (Some(name), Some(rt)) => {
            let seed = matches!(route.kind, RouteKind::Hyper).then_some(job.seed);
            match rt.run_attention(name, job.heads, job.n, job.d, &job.q, &job.k, &job.v, seed)
            {
                Ok(out) => (Ok(out), Backend::Artifact(name.clone())),
                Err(e) => {
                    // artifact failure degrades to substrate
                    eprintln!("engine: artifact {name} failed ({e:#}); substrate fallback");
                    (Ok(execute_substrate(&job, route.kind, rc)), Backend::Substrate)
                }
            }
        }
        _ => (Ok(execute_substrate(&job, route.kind, rc)), Backend::Substrate),
    };

    let exec_us = exec_start.elapsed().as_micros() as u64;
    metrics.queue_latency.record(queue_us);
    metrics.exec_latency.record(exec_us);
    metrics.e2e_latency.record(queue_us + exec_us);
    match backend {
        Backend::Artifact(_) => {
            metrics.artifact_jobs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        Backend::Substrate => {
            metrics.substrate_jobs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }

    let response = result.map(|out| AttnResponse { id: job.id, out, backend, queue_us, exec_us });
    match &response {
        Ok(_) => {
            metrics.jobs_completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        Err(_) => {
            metrics.jobs_failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }
    let _ = respond.send(response);
}

fn engine_loop(
    rx: Receiver<EngineMsg>,
    artifacts_dir: Option<PathBuf>,
    rc: RouterConfig,
    metrics: Arc<Metrics>,
    sub_tx: SyncSender<EngineMsg>,
    n_workers: usize,
) {
    // Runtime is created lazily on this thread (PjRtClient is !Send).
    let runtime: Option<Runtime> = artifacts_dir.and_then(|dir| match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("engine: failed to open artifacts at {dir:?}: {e:#}; substrate only");
            None
        }
    });

    while let Ok(msg) = rx.recv() {
        let batch = match msg {
            EngineMsg::Batch(b) => b,
            EngineMsg::Shutdown => break,
        };
        metrics.record_batch(batch.len());
        // route the whole batch to its lane (batch keys are per-route, so
        // a batch is uniformly artifact or substrate)
        let is_artifact = batch
            .first()
            .map(|i| i.route.artifact.is_some() && runtime.is_some())
            .unwrap_or(false);
        if is_artifact {
            for item in batch {
                execute_one(item, runtime.as_ref(), &rc, &metrics);
            }
        } else {
            // forward to the substrate pool; if it is gone, run inline
            if let Err(e) = sub_tx.send(EngineMsg::Batch(batch)) {
                if let EngineMsg::Batch(batch) = e.0 {
                    for item in batch {
                        execute_one(item, None, &rc, &metrics);
                    }
                }
            }
        }
    }
    for _ in 0..n_workers {
        let _ = sub_tx.send(EngineMsg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact;
    use crate::coordinator::request::ModePreference;
    use crate::linalg::MatRef;
    use crate::rng::Rng;

    fn job(n: usize, causal: bool, seed: i32) -> AttnJob {
        let (h, d) = (2, 16);
        let mut rng = Rng::new(seed as u64);
        AttnJob {
            id: 9,
            heads: h,
            n,
            d,
            q: rng.normal_vec(h * n * d),
            k: rng.normal_vec(h * n * d),
            v: rng.normal_vec(h * n * d),
            causal,
            mode: ModePreference::Auto,
            seed,
        }
    }

    #[test]
    fn pick_block_divides() {
        assert_eq!(pick_block(128, 32), 32);
        assert_eq!(pick_block(96, 64), 48);
        assert_eq!(pick_block(97, 64), 1); // prime
        assert_eq!(pick_block(4, 64), 4);
        // O(√n) divisor enumeration: prime / power-of-two / odd composite
        assert_eq!(pick_block(1009, 256), 1); // prime
        assert_eq!(pick_block(1 << 14, 256), 256); // power of two
        assert_eq!(pick_block(3 * 5 * 7 * 11, 100), 77); // odd composite
        assert_eq!(pick_block(225, 100), 75); // odd composite square
    }

    #[test]
    fn substrate_exact_matches_reference() {
        let j = job(48, false, 3);
        let rc = RouterConfig::default();
        let out = execute_substrate(&j, RouteKind::Exact, &rc);
        // head 0 vs naive, through zero-copy views of the job buffers
        let per = 48 * 16;
        let m = |x: &[f32]| MatRef::new(48, 16, &x[..per]).to_mat();
        let exact = exact::naive_attention(&m(&j.q), &m(&j.k), &m(&j.v), false, None);
        let got = MatRef::new(48, 16, &out[..per]).to_mat();
        assert!(exact.max_abs_diff(&got) < 1e-5);
    }

    #[test]
    fn substrate_hyper_runs_all_shapes() {
        let rc = RouterConfig { block: 16, samples: 16, causal_base: 32, ..Default::default() };
        for n in [16usize, 48, 97, 128] {
            for causal in [false, true] {
                let j = job(n, causal, 1);
                let out = execute_substrate(&j, RouteKind::Hyper, &rc);
                assert_eq!(out.len(), 2 * n * 16);
                assert!(out.iter().all(|x| x.is_finite()), "n={n} causal={causal}");
            }
        }
    }

    #[test]
    fn substrate_deterministic() {
        let rc = RouterConfig { block: 16, samples: 16, ..Default::default() };
        let j = job(64, false, 5);
        let a = execute_substrate(&j, RouteKind::Hyper, &rc);
        let b = execute_substrate(&j, RouteKind::Hyper, &rc);
        assert_eq!(a, b);
    }

    /// The explicit-hyper prime-n guard that used to live here as an
    /// `if block < 8` now comes from the op's AutoPolicy — same result.
    #[test]
    fn substrate_prime_n_hyper_degrades_to_exact() {
        let rc = RouterConfig { block: 256, samples: 16, ..Default::default() };
        let j = job(97, false, 2);
        let out = execute_substrate(&j, RouteKind::Hyper, &rc);
        let per = 97 * 16;
        let m = |x: &[f32]| MatRef::new(97, 16, &x[..per]).to_mat();
        let exact = exact::naive_attention(&m(&j.q), &m(&j.k), &m(&j.v), false, None);
        let got = MatRef::new(97, 16, &out[..per]).to_mat();
        assert!(exact.max_abs_diff(&got) < 1e-5, "prime n must run exact");
    }
}

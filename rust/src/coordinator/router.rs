//! Routing policy: which algorithm and which backend serves a job.
//!
//! Mirrors the paper's deployment recipe: exact attention below a length
//! threshold (the approximation only pays off on long contexts), and
//! HyperAttention above it.  An AOT artifact is selected when the
//! manifest has an exact (kind, causal, h, n, d) match; anything else
//! falls back to the pure-Rust substrate (shape-exact, no padding: the
//! softmax denominator is not padding-safe in the non-causal case).

use super::request::{AttnJob, ModePreference};
use crate::attention::op::AutoPolicy;
use crate::runtime::Manifest;

/// Algorithm choice after policy is applied.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RouteKind {
    Exact,
    Hyper,
}

/// Full routing decision for one job.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Route {
    pub kind: RouteKind,
    pub causal: bool,
    /// artifact name, or None for the substrate path
    pub artifact: Option<String>,
    /// streaming-session lane: decode steps (and session closes) of all
    /// live sessions share this one batch key, so they coalesce into
    /// decode batches instead of re-entering the queue as full jobs
    pub decode: bool,
}

impl Route {
    /// The shared batch key of the streaming decode lane.
    pub fn decode_key() -> Route {
        Route { kind: RouteKind::Exact, causal: false, artifact: None, decode: true }
    }
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// jobs with n >= this use HyperAttention when mode = Auto
    pub hyper_threshold: usize,
    /// substrate hyper parameters (block, samples) for fallback execution
    pub block: usize,
    pub samples: usize,
    /// causal recursion base
    pub causal_base: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { hyper_threshold: 1024, block: 256, samples: 256, causal_base: 1024 }
    }
}

impl RouterConfig {
    /// The documented routing policy this coordinator applies for
    /// `ModePreference::Auto` — the same [`AutoPolicy`] the execution
    /// op uses, parameterized by this router's threshold.
    pub fn auto_policy(&self) -> AutoPolicy {
        AutoPolicy { hyper_threshold: self.hyper_threshold, ..Default::default() }
    }
}

/// The router: policy + artifact index.
#[derive(Clone, Debug)]
pub struct Router {
    pub config: RouterConfig,
    /// (kind, causal, heads, n, d) -> artifact name
    index: Vec<(RouteKind, bool, usize, usize, usize, String)>,
}

impl Router {
    pub fn new(config: RouterConfig, manifest: Option<&Manifest>) -> Self {
        let mut index = Vec::new();
        if let Some(m) = manifest {
            for a in &m.artifacts {
                let kind = match a.kind.as_str() {
                    "attn_exact" => RouteKind::Exact,
                    "attn_hyper" => RouteKind::Hyper,
                    _ => continue,
                };
                index.push((kind, a.causal, a.heads, a.n, a.d, a.name.clone()));
            }
        }
        Router { config, index }
    }

    /// Algorithm policy: honor explicit preference, else the documented
    /// length-threshold rule of [`AutoPolicy`].  Only the threshold row
    /// of the table applies here — the shape-fit degradation rows are
    /// applied at execution time inside the op itself, so routing stays
    /// monotone in n (a prime-length job still *routes* to the hyper
    /// family and then degrades to exact streaming at execution).
    pub fn pick_kind(&self, job: &AttnJob) -> RouteKind {
        match job.mode {
            ModePreference::Exact => RouteKind::Exact,
            ModePreference::Hyper => RouteKind::Hyper,
            ModePreference::Auto => {
                if job.n >= self.config.auto_policy().hyper_threshold {
                    RouteKind::Hyper
                } else {
                    RouteKind::Exact
                }
            }
        }
    }

    /// Full routing decision.
    pub fn route(&self, job: &AttnJob) -> Route {
        let kind = self.pick_kind(job);
        let artifact = self
            .index
            .iter()
            .find(|(k, c, h, n, d, _)| {
                *k == kind && *c == job.causal && *h == job.heads && *n == job.n && *d == job.d
            })
            .map(|(_, _, _, _, _, name)| name.clone());
        Route { kind, causal: job.causal, artifact, decode: false }
    }

    /// Batching key: jobs sharing a key may be executed in one batch.
    pub fn batch_key(&self, job: &AttnJob) -> Route {
        self.route(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ModePreference;

    fn job(n: usize, mode: ModePreference, causal: bool) -> AttnJob {
        let (h, d) = (4, 64);
        AttnJob {
            id: 0,
            heads: h,
            n,
            d,
            q: vec![0.0; h * n * d],
            k: vec![0.0; h * n * d],
            v: vec![0.0; h * n * d],
            causal,
            mode,
            seed: 0,
        }
    }

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{"format": "hlo-text", "artifacts": [
            {"name": "attn_exact_128", "path": "a", "kind": "attn_exact",
             "causal": false, "heads": 4, "n": 128, "d": 64},
            {"name": "attn_hyper_2048", "path": "b", "kind": "attn_hyper",
             "causal": false, "heads": 4, "n": 2048, "d": 64},
            {"name": "attn_hyper_causal_2048", "path": "c", "kind": "attn_hyper",
             "causal": true, "heads": 4, "n": 2048, "d": 64}
        ]}"#,
        )
        .unwrap()
    }

    #[test]
    fn auto_threshold_policy() {
        let r = Router::new(RouterConfig { hyper_threshold: 1024, ..Default::default() }, None);
        assert_eq!(r.pick_kind(&job(512, ModePreference::Auto, false)), RouteKind::Exact);
        assert_eq!(r.pick_kind(&job(1024, ModePreference::Auto, false)), RouteKind::Hyper);
        assert_eq!(r.pick_kind(&job(8192, ModePreference::Auto, false)), RouteKind::Hyper);
    }

    #[test]
    fn explicit_mode_wins() {
        let r = Router::new(RouterConfig::default(), None);
        assert_eq!(r.pick_kind(&job(16, ModePreference::Hyper, false)), RouteKind::Hyper);
        assert_eq!(r.pick_kind(&job(1 << 20, ModePreference::Exact, false)), RouteKind::Exact);
    }

    #[test]
    fn artifact_exact_shape_match_only() {
        let m = manifest();
        let r = Router::new(RouterConfig { hyper_threshold: 1024, ..Default::default() }, Some(&m));
        // exact-shape artifact hit
        let route = r.route(&job(128, ModePreference::Exact, false));
        assert_eq!(route.artifact.as_deref(), Some("attn_exact_128"));
        // off-shape: substrate
        let route = r.route(&job(96, ModePreference::Exact, false));
        assert_eq!(route.artifact, None);
        // causal variant respected
        let route = r.route(&job(2048, ModePreference::Hyper, true));
        assert_eq!(route.artifact.as_deref(), Some("attn_hyper_causal_2048"));
        let route = r.route(&job(2048, ModePreference::Hyper, false));
        assert_eq!(route.artifact.as_deref(), Some("attn_hyper_2048"));
    }

    #[test]
    fn no_manifest_always_substrate() {
        let r = Router::new(RouterConfig::default(), None);
        for n in [64, 128, 2048] {
            assert_eq!(r.route(&job(n, ModePreference::Auto, false)).artifact, None);
        }
    }

    #[test]
    fn batch_key_groups_same_route() {
        let m = manifest();
        let r = Router::new(RouterConfig::default(), Some(&m));
        let a = r.batch_key(&job(128, ModePreference::Exact, false));
        let b = r.batch_key(&job(128, ModePreference::Exact, false));
        assert_eq!(a, b);
        let c = r.batch_key(&job(128, ModePreference::Hyper, false));
        assert_ne!(a, c);
    }
}

//! Server wiring: submit → route → dynamic batch → engine → response.
//!
//! Pure `std::thread` + channels (no async runtime in this tree): a
//! batcher thread hosts the [`BatchQueue`] state machine, flushing on
//! size or deadline via `recv_timeout`; the engine thread hosts PJRT +
//! the Rust substrate.  Backpressure: both channels are bounded, so a
//! full pipeline pushes back on `submit()`.
//!
//! Streaming sessions: [`Server::open_session`] prefills a prompt into
//! a per-session KV cache held by the engine, [`Server::decode`] feeds
//! one token per call (decode steps from all live sessions coalesce
//! under one batch key), and [`Server::close_session`] frees the cache.
//! Shared-prefix traffic registers the common prompt once
//! ([`Server::register_prefix`]) and opens sessions against the key
//! ([`Server::open_session_with_prefix`]): each open forks the pinned
//! cache by refcount bumps (copy-on-write tail), so N sessions over a
//! P-page prefix cost P + N·(private tail) pages instead of N·P.
//! The decode lane flows through the continuous-batching scheduler
//! ([`super::scheduler`]): submissions bypass the batcher's wait (the
//! scheduler does its own per-tick coalescing) and are processed in
//! **submission order** — at most one step per session per tick — so
//! pipelined same-session decode steps now execute in the order they
//! were submitted, and a [`Server::ping`] submitted after N decode
//! steps resolves only after those steps' tokens are emitted (the FIFO
//! barrier).  `DecodeJob::pos` remains the belt-and-braces guard: a
//! step landing at the wrong cache position is still rejected
//! explicitly.  [`ServerConfig::sched`] sets the fused-batch width and
//! the speculative draft-lane knobs (`draft_k`/`draft_window`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchConfig, BatchQueue};
use super::engine::{self, CacheConfig, EngineMsg, Reply, Work, WorkItem};
use super::metrics::{CacheGauges, Metrics};
use super::request::{AttnJob, AttnResponse, DecodeJob, DecodeResponse, SessionId};
use super::router::{Route, Router, RouterConfig};
use super::scheduler::SchedConfig;
use crate::linalg::PagePool;
use crate::runtime::Manifest;

/// Full coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub router: RouterConfig,
    pub batch: BatchConfig,
    /// KV-cache memory subsystem: shared page pool size/budget,
    /// per-session eviction policy, idle-session TTL
    pub cache: CacheConfig,
    /// Continuous-batching scheduler: fused decode-batch width
    /// (`max_batch`), the speculative draft lane (`draft_k` shadow
    /// steps per accept/rollback window over a fork degraded to
    /// `draft_window` rows; `draft_k = 0` disables speculation), and
    /// scheduler-interleaved chunked prefill (`prefill_chunk` rows per
    /// tick; 0 disables — long causal opens/fulls above the chunk size
    /// then stream in alongside decode instead of stalling a worker)
    pub sched: SchedConfig,
    /// directory with manifest.json + *.hlo.txt; None = substrate only
    pub artifacts_dir: Option<PathBuf>,
    /// bounded queue depths (submit channel & engine channel)
    pub queue_depth: usize,
    /// Default per-request deadline (None = no deadline, the default).
    /// A request still queued when its deadline passes resolves with
    /// [`super::request::DEADLINE_EXPIRED`] before the engine does any
    /// pool or session work for it — the load-shedding backstop that
    /// keeps a backed-up queue from burning compute on answers nobody
    /// is waiting for.  Closes and prefix releases are exempt (they
    /// free memory and must always run).  Per-request overrides:
    /// [`Server::submit_with_deadline`] /
    /// [`Server::decode_with_deadline`].
    pub request_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            router: RouterConfig::default(),
            batch: BatchConfig::default(),
            cache: CacheConfig::default(),
            sched: SchedConfig::default(),
            artifacts_dir: None,
            queue_depth: 256,
            request_timeout: None,
        }
    }
}

impl ServerConfig {
    pub fn substrate_only() -> Self {
        ServerConfig::default()
    }

    pub fn with_artifacts(dir: impl Into<PathBuf>) -> Self {
        ServerConfig { artifacts_dir: Some(dir.into()), ..Default::default() }
    }
}

struct Submission {
    work: Work,
    respond: Reply,
    submitted: Instant,
    deadline: Option<Instant>,
}

/// A pending response handle (await with [`Ticket::wait`]).
pub struct Ticket {
    rx: Receiver<Result<AttnResponse, String>>,
}

impl Ticket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<AttnResponse, String> {
        self.rx
            .recv()
            .map_err(|_| "engine dropped job".to_string())?
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, dur: Duration) -> Result<AttnResponse, String> {
        match self.rx.recv_timeout(dur) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err("timed out".into()),
            Err(RecvTimeoutError::Disconnected) => Err("engine dropped job".into()),
        }
    }
}

/// A pending decode-step handle (await with [`DecodeTicket::wait`]).
pub struct DecodeTicket {
    rx: Receiver<Result<DecodeResponse, String>>,
}

impl DecodeTicket {
    /// Block until the decode step completes.
    pub fn wait(self) -> Result<DecodeResponse, String> {
        self.rx
            .recv()
            .map_err(|_| "engine dropped decode step".to_string())?
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, dur: Duration) -> Result<DecodeResponse, String> {
        match self.rx.recv_timeout(dur) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err("timed out".into()),
            Err(RecvTimeoutError::Disconnected) => Err("engine dropped decode step".into()),
        }
    }
}

/// Handle to a running coordinator.
pub struct Server {
    submit_tx: Option<SyncSender<Submission>>,
    metrics: Arc<Metrics>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
    next_session: AtomicU64,
    /// submission order of prefix register/release ops — the engine
    /// resolves cross-lane reordering by "newest submission wins"
    prefix_seq: AtomicU64,
    /// default per-request deadline ([`ServerConfig::request_timeout`])
    request_timeout: Option<Duration>,
    /// introspection handles into the KV memory subsystem
    pool: PagePool,
    sessions: engine::SessionMap,
    prefixes: engine::PrefixMap,
}

impl Server {
    /// Start the coordinator (spawns the batcher + engine threads).
    /// Fails with a descriptive error if the OS refuses a thread — no
    /// half-started server is ever returned.
    pub fn start(config: ServerConfig) -> Result<Self, String> {
        let metrics = Arc::new(Metrics::new());
        let depth = config.queue_depth.max(1);

        // Router reads the manifest here; the engine re-opens the runtime
        // on its own thread (PjRtClient is thread-affine).
        let manifest = config
            .artifacts_dir
            .as_ref()
            .and_then(|d| Manifest::load(d.join("manifest.json")).ok());
        let router = Router::new(config.router.clone(), manifest.as_ref());

        let (engine_tx, engine_handle, pool, sessions, prefixes) = engine::spawn(
            config.artifacts_dir.clone(),
            config.router.clone(),
            config.cache,
            config.sched,
            metrics.clone(),
            depth,
        )?;

        let (submit_tx, submit_rx) = sync_channel::<Submission>(depth);
        let batch_cfg = config.batch;
        let prefill_chunk = config.sched.prefill_chunk;

        let engine_tx_failsafe = engine_tx.clone();
        let batcher_spawn = std::thread::Builder::new()
            .name("hyperattn-batcher".into())
            .spawn(move || {
                let mut queue: BatchQueue<Route, WorkItem> = BatchQueue::new(batch_cfg);
                loop {
                    // Wait for the next submission or the flush deadline.
                    let msg = match queue.next_deadline() {
                        Some(deadline) => {
                            let now = Instant::now();
                            if deadline <= now {
                                // deadline already passed: flush, don't block
                                for (_, batch) in queue.tick(now) {
                                    if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                        return;
                                    }
                                }
                                continue;
                            }
                            match submit_rx.recv_timeout(deadline - now) {
                                Ok(s) => Some(s),
                                Err(RecvTimeoutError::Timeout) => None,
                                Err(RecvTimeoutError::Disconnected) => break,
                            }
                        }
                        None => match submit_rx.recv() {
                            Ok(s) => Some(s),
                            Err(_) => break,
                        },
                    };
                    match msg {
                        Some(sub) => {
                            let route = match &sub.work {
                                Work::Full(job) => {
                                    let mut r = router.route(job);
                                    // a long causal one-shot (no artifact
                                    // lane for it) streams through the
                                    // scheduler's chunked-ingest path
                                    // instead of stalling a worker
                                    if prefill_chunk > 0
                                        && job.causal
                                        && job.n > prefill_chunk
                                        && r.artifact.is_none()
                                    {
                                        r.decode = true;
                                    }
                                    r
                                }
                                Work::Open { job, prefix, .. } => {
                                    // sessions are shape-dynamic: always
                                    // the substrate lane.  Long causal
                                    // plain opens reroute to the decode
                                    // lane for chunked ingest (prefix
                                    // forks keep the monolithic path —
                                    // their validation loop is fork-
                                    // scoped, and the suffix is short)
                                    let mut r = router.route(job);
                                    r.artifact = None;
                                    if prefill_chunk > 0
                                        && prefix.is_none()
                                        && job.causal
                                        && job.n > prefill_chunk
                                    {
                                        r.decode = true;
                                    }
                                    r
                                }
                                Work::RegisterPrefix { job, .. } => {
                                    // prefix caches are forked from
                                    // sessions: substrate lane, monolithic
                                    let mut r = router.route(job);
                                    r.artifact = None;
                                    r
                                }
                                // decode steps of all live sessions share
                                // one lane key; coalescing across
                                // sessions is the scheduler's job, so
                                // this lane skips the batcher wait below
                                // (pings ride the same lane: a probe
                                // measures the real pipeline, not a
                                // privileged shortcut)
                                Work::Decode(_)
                                | Work::Close { .. }
                                | Work::ReleasePrefix { .. }
                                | Work::Ping => Route::decode_key(),
                            };
                            let item = WorkItem {
                                work: sub.work,
                                route: route.clone(),
                                submitted: sub.submitted,
                                respond: sub.respond,
                                deadline: sub.deadline,
                            };
                            if route.decode {
                                // The decode lane bypasses the dynamic
                                // batcher's wait entirely: the scheduler
                                // does its own per-tick coalescing, and
                                // forwarding each item immediately keeps
                                // the lane in strict submission order
                                // (the ping FIFO barrier) with no
                                // `max_wait` latency tax per token.
                                if engine_tx.send(EngineMsg::Batch(vec![item])).is_err() {
                                    return;
                                }
                            } else if let Some((_, batch)) =
                                queue.push(route, item, Instant::now())
                            {
                                if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                    return;
                                }
                            }
                        }
                        None => {
                            for (_, batch) in queue.tick(Instant::now()) {
                                if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                }
                // channel closed: drain and stop the engine
                for (_, batch) in queue.drain() {
                    let _ = engine_tx.send(EngineMsg::Batch(batch));
                }
                let _ = engine_tx.send(EngineMsg::Shutdown);
            });
        let batcher_handle = match batcher_spawn {
            Ok(h) => h,
            Err(e) => {
                // tear the engine down before reporting: no orphan thread
                let _ = engine_tx_failsafe.send(EngineMsg::Shutdown);
                let _ = engine_handle.join();
                return Err(format!("spawn batcher thread: {e}"));
            }
        };

        Ok(Server {
            submit_tx: Some(submit_tx),
            metrics,
            engine_handle: Some(engine_handle),
            batcher_handle: Some(batcher_handle),
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            prefix_seq: AtomicU64::new(1),
            request_timeout: config.request_timeout,
            pool,
            sessions,
            prefixes,
        })
    }

    /// The deadline stamped on a request submitted now, per
    /// [`ServerConfig::request_timeout`].
    fn default_deadline(&self) -> Option<Instant> {
        self.request_timeout.map(|t| Instant::now() + t)
    }

    fn send(&self, work: Work, respond: Reply, deadline: Option<Instant>) -> Result<(), String> {
        self.submit_tx
            .as_ref()
            .expect("server running")
            .send(Submission { work, respond, submitted: Instant::now(), deadline })
            .map_err(|_| "coordinator shut down".to_string())
    }

    /// Submit a job; returns a [`Ticket`] to wait on.  Blocks only if the
    /// submit queue is full (backpressure).  The ticket carries the
    /// server's default deadline ([`ServerConfig::request_timeout`]).
    pub fn submit(&self, job: AttnJob) -> Result<Ticket, String> {
        self.submit_inner(job, self.default_deadline())
    }

    /// [`Server::submit`] with an explicit deadline: if the job is
    /// still queued when `deadline` passes, it resolves with
    /// [`super::request::DEADLINE_EXPIRED`] instead of executing.
    pub fn submit_with_deadline(&self, job: AttnJob, deadline: Instant) -> Result<Ticket, String> {
        self.submit_inner(job, Some(deadline))
    }

    fn submit_inner(&self, mut job: AttnJob, deadline: Option<Instant>) -> Result<Ticket, String> {
        job.validate()?;
        if job.id == 0 {
            job.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.send(Work::Full(job), Reply::Full(tx), deadline)?;
        Ok(Ticket { rx })
    }

    /// Submit and block until completion.
    pub fn submit_wait(&self, job: AttnJob) -> Result<AttnResponse, String> {
        self.submit(job)?.wait()
    }

    /// Open a streaming session: the job's q/k/v is the prompt, which
    /// is prefilled into a fresh per-session KV cache.  Returns the
    /// session id plus a [`Ticket`] for the prompt's attention output.
    /// Subsequent [`Server::decode`] steps extend the session one token
    /// at a time; [`Server::close_session`] frees the cache.  Wait for
    /// the prefill ticket before submitting decode steps — the session
    /// is registered when the prefill completes.
    pub fn open_session(&self, job: AttnJob) -> Result<(SessionId, Ticket), String> {
        self.open_session_with_prefix(None, job)
    }

    /// [`Server::open_session`] with an optional registered-prefix key.
    /// With `Some(key)`, the job's q/k/v rows are the **continuation**
    /// of the pinned prefix (positions `prefix_len..`): the engine
    /// forks the prefix cache in O(pages) refcount bumps — no prefix
    /// row is copied or recomputed, shared pages are charged once — and
    /// prefills only the suffix.  The prefix must have been registered
    /// via [`Server::register_prefix`] with the same (heads, d) shape
    /// and compatible causality/scale; admission control charges the
    /// session only for its private tail (the copy-on-write split of
    /// the prefix's partial tail page plus the suffix's fresh pages).
    pub fn open_session_with_prefix(
        &self,
        prefix: Option<&str>,
        mut job: AttnJob,
    ) -> Result<(SessionId, Ticket), String> {
        job.validate()?;
        if job.id == 0 {
            job.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let session = self.next_session.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.send(
            Work::Open { session, job, prefix: prefix.map(str::to_string) },
            Reply::Full(tx),
            self.default_deadline(),
        )?;
        Ok((session, Ticket { rx }))
    }

    /// Ingest a prompt into a pinned, shareable prefix cache under
    /// `key` — the system-prompt / few-shot-preamble / RAG-scaffold
    /// path: register the common prefix once, then every
    /// [`Server::open_session_with_prefix`] call forks it instead of
    /// re-ingesting it.  Returns a [`Ticket`] for the prefix's own
    /// attention output; wait for it before opening sessions against
    /// the key.  Re-registering a key replaces the old cache.  Pinned
    /// prefixes are exempt from LRU eviction and the TTL sweep; drop
    /// them with [`Server::release_prefix`].
    pub fn register_prefix(
        &self,
        key: impl Into<String>,
        mut job: AttnJob,
    ) -> Result<Ticket, String> {
        job.validate()?;
        if job.id == 0 {
            job.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let seq = self.prefix_seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.send(
            Work::RegisterPrefix { key: key.into(), seq, job },
            Reply::Full(tx),
            self.default_deadline(),
        )?;
        Ok(Ticket { rx })
    }

    /// Unpin a registered prefix, releasing the registry's page
    /// handles.  Fire-and-forget; pages still shared by live forked
    /// sessions stay resident until those sessions close.  Safe to call
    /// without waiting on the register ticket: ops are sequence-stamped
    /// at submission, so even if the release overtakes its register
    /// across batch lanes, the register will not resurrect the key.
    pub fn release_prefix(&self, key: impl Into<String>) -> Result<(), String> {
        let seq = self.prefix_seq.fetch_add(1, Ordering::Relaxed);
        // releases free memory: never deadlined
        self.send(Work::ReleasePrefix { key: key.into(), seq }, Reply::None, None)
    }

    /// Submit one decode step for a live session.  Decode steps from
    /// all sessions share one batch key, so concurrent streams coalesce
    /// into decode batches instead of re-entering as full jobs.  The
    /// ticket carries the server's default deadline.
    pub fn decode(&self, job: DecodeJob) -> Result<DecodeTicket, String> {
        self.decode_inner(job, self.default_deadline())
    }

    /// [`Server::decode`] with an explicit deadline: a step still
    /// queued when `deadline` passes resolves with
    /// [`super::request::DEADLINE_EXPIRED`] and leaves the session's
    /// cache untouched (the client may retry with a fresh deadline).
    pub fn decode_with_deadline(
        &self,
        job: DecodeJob,
        deadline: Instant,
    ) -> Result<DecodeTicket, String> {
        self.decode_inner(job, Some(deadline))
    }

    fn decode_inner(
        &self,
        job: DecodeJob,
        deadline: Option<Instant>,
    ) -> Result<DecodeTicket, String> {
        job.validate()?;
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.send(Work::Decode(job), Reply::Decode(tx), deadline)?;
        Ok(DecodeTicket { rx })
    }

    /// Submit a decode step and block until it completes.
    pub fn decode_wait(&self, job: DecodeJob) -> Result<DecodeResponse, String> {
        self.decode(job)?.wait()
    }

    /// Close a streaming session, dropping its KV cache.  Fire-and-
    /// forget: queued decode steps ahead of the close still run.
    /// Closes free memory and are never deadlined.
    pub fn close_session(&self, session: SessionId) -> Result<(), String> {
        self.send(Work::Close { session }, Reply::None, None)
    }

    /// End-to-end health probe: a ping rides the decode batch lane
    /// through router, batcher, and engine, and answers `Ok(())` when
    /// the pipeline is live.  Returns an error if the probe does not
    /// answer within `timeout` (wedged pipeline) or if the server is
    /// shutting down — which is exactly what a load balancer's
    /// liveness check wants to know.
    pub fn ping(&self, timeout: Duration) -> Result<(), String> {
        let (tx, rx) = sync_channel(1);
        self.send(Work::Ping, Reply::Ping(tx), None)?;
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(format!("ping timed out after {timeout:?}")),
            Err(RecvTimeoutError::Disconnected) => Err("coordinator shut down".into()),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Snapshot of the KV memory subsystem: page-pool counters
    /// (including shared-page and copy-on-write gauges), utilization
    /// against the budget, and per-session / per-prefix residency.
    pub fn cache_gauges(&self) -> CacheGauges {
        engine::cache_gauges(&self.sessions, &self.prefixes, &self.pool, &self.metrics)
    }

    /// Graceful shutdown: drain queues, stop both threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Closing the submit channel makes the batcher drain + stop, which
        // in turn shuts the engine down.
        self.submit_tx.take();
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Backend, ModePreference};
    use crate::rng::Rng;

    fn mk_job(n: usize, mode: ModePreference, causal: bool, seed: i32) -> AttnJob {
        let (h, d) = (2, 16);
        let mut rng = Rng::new(seed as u64);
        AttnJob {
            id: 0,
            heads: h,
            n,
            d,
            q: rng.normal_vec(h * n * d),
            k: rng.normal_vec(h * n * d),
            v: rng.normal_vec(h * n * d),
            causal,
            mode,
            seed,
        }
    }

    #[test]
    fn substrate_roundtrip() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let resp = server
            .submit_wait(mk_job(32, ModePreference::Exact, false, 1))
            .unwrap();
        assert_eq!(resp.out.len(), 2 * 32 * 16);
        assert_eq!(resp.backend, Backend::Substrate);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        server.shutdown();
    }

    #[test]
    fn concurrent_jobs_all_complete() {
        let server = Arc::new(Server::start(ServerConfig::substrate_only()).unwrap());
        let mut handles = Vec::new();
        for i in 0..24 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let mode = if i % 2 == 0 {
                    ModePreference::Exact
                } else {
                    ModePreference::Hyper
                };
                s.submit_wait(mk_job(64, mode, i % 3 == 0, i))
            }));
        }
        for h in handles {
            let resp = h.join().unwrap().unwrap();
            assert!(resp.out.iter().all(|x| x.is_finite()));
        }
        let m = server.metrics();
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 24);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn invalid_job_rejected_before_queue() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let mut j = mk_job(16, ModePreference::Exact, false, 0);
        j.q.pop();
        assert!(server.submit(j).is_err());
        assert_eq!(server.metrics().jobs_submitted.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn batching_accumulates() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.batch.max_batch = 4;
        cfg.batch.max_wait = Duration::from_millis(50);
        let server = Arc::new(Server::start(cfg).unwrap());
        let mut handles = Vec::new();
        for i in 0..8 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                s.submit_wait(mk_job(32, ModePreference::Exact, false, i))
            }));
        }
        for h in handles {
            h.join().unwrap().unwrap();
        }
        // 8 same-route jobs with max_batch 4: mean batch size must beat 1
        assert!(server.metrics().mean_batch_size() > 1.0);
    }

    #[test]
    fn streaming_session_roundtrip() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let (h, n, d) = (2usize, 24usize, 16usize);
        let (sid, ticket) = server
            .open_session(mk_job(n, ModePreference::Exact, true, 7))
            .unwrap();
        let pre = ticket.wait().unwrap();
        assert_eq!(pre.out.len(), h * n * d);
        assert!(pre.out.iter().all(|x| x.is_finite()));
        let mut rng = Rng::new(99);
        for t in 0..5usize {
            let dj = DecodeJob {
                session: sid,
                heads: h,
                d,
                pos: None,
                q: rng.normal_vec(h * d),
                k: rng.normal_vec(h * d),
                v: rng.normal_vec(h * d),
            };
            let resp = server.decode_wait(dj).unwrap();
            assert_eq!(resp.pos, n + t);
            assert_eq!(resp.out.len(), h * d);
            assert!(resp.out.iter().all(|x| x.is_finite()));
        }
        let m = server.metrics();
        assert_eq!(m.sessions_opened.load(Ordering::Relaxed), 1);
        assert_eq!(m.decode_steps.load(Ordering::Relaxed), 5);
        // streaming work reconciles the jobs counters too
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 6); // 1 open + 5 decode
        // the ordering guard: a step claiming a stale position errors
        let stale = DecodeJob {
            session: sid,
            heads: h,
            d,
            pos: Some(0), // session is at n + 5
            q: rng.normal_vec(h * d),
            k: rng.normal_vec(h * d),
            v: rng.normal_vec(h * d),
        };
        assert!(server.decode_wait(stale).is_err(), "out-of-order step must error");
        server.close_session(sid).unwrap();
        server.shutdown();
    }

    #[test]
    fn decode_validation_and_unknown_session() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        // unknown session: explicit error, not a hang
        let dj = DecodeJob {
            session: 777,
            heads: 1,
            d: 8,
            pos: None,
            q: vec![0.0; 8],
            k: vec![0.0; 8],
            v: vec![0.0; 8],
        };
        assert!(server.decode_wait(dj).is_err());
        // invalid shape rejected before the queue
        let bad = DecodeJob {
            session: 1,
            heads: 1,
            d: 8,
            pos: None,
            q: vec![0.0; 7],
            k: vec![0.0; 8],
            v: vec![0.0; 8],
        };
        assert!(server.decode(bad).is_err());
        server.shutdown();
    }

    /// Shutdown must resolve every pending ticket — queued streaming
    /// work is flushed with an explicit error instead of leaking the
    /// oneshot senders.
    #[test]
    fn shutdown_resolves_all_pending_tickets() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let (sid, t0) = server
            .open_session(mk_job(16, ModePreference::Exact, true, 1))
            .unwrap();
        let mut tickets = Vec::new();
        let mut rng = Rng::new(5);
        for _ in 0..8 {
            let dj = DecodeJob {
                session: sid,
                heads: 2,
                d: 16,
                pos: None,
                q: rng.normal_vec(32),
                k: rng.normal_vec(32),
                v: rng.normal_vec(32),
            };
            tickets.push(server.decode(dj).unwrap());
        }
        drop(server); // graceful shutdown via Drop
        let _ = t0.wait(); // must resolve either way
        for t in tickets {
            // resolved: Ok (ran before the flush) or the explicit error
            let _ = t.wait_timeout(Duration::from_secs(10));
        }
    }

    /// Multi-tenant page budget: opens beyond the pool LRU-evict idle
    /// sessions; decode appends that outgrow the pool do the same; and
    /// the evicted session's id is gone from the table.
    #[test]
    fn page_budget_admission_lru_eviction() {
        let mut cfg = ServerConfig::substrate_only();
        // mk_job shape is (h=2, d=16): 8 rows per page, so the n=24
        // prompt needs exactly 3 pages; budget 6 fits two sessions
        cfg.cache.page_elems = 3 * 2 * 16 * 8;
        cfg.cache.budget_pages = Some(6);
        let server = Server::start(cfg).unwrap();
        let open = |seed: i32| {
            let (sid, t) = server
                .open_session(mk_job(24, ModePreference::Exact, true, seed))
                .unwrap();
            t.wait().unwrap();
            sid
        };
        let s1 = open(1);
        let s2 = open(2);
        assert_eq!(server.cache_gauges().pages_in_use, 6);
        // third session: pool dry -> the LRU session (s1) is evicted
        let s3 = open(3);
        let m = server.metrics();
        assert!(m.sessions_evicted.load(Ordering::Relaxed) >= 1);
        let dj = |sid| {
            let mut rng = Rng::new(9 + sid);
            DecodeJob {
                session: sid,
                heads: 2,
                d: 16,
                pos: None,
                q: rng.normal_vec(32),
                k: rng.normal_vec(32),
                v: rng.normal_vec(32),
            }
        };
        assert!(server.decode_wait(dj(s1)).is_err(), "evicted session is gone");
        // s3's 25th row needs a 4th page: evicts the idle s2 and succeeds
        let resp = server.decode_wait(dj(s3)).unwrap();
        assert_eq!(resp.pos, 24);
        assert!(server.decode_wait(dj(s2)).is_err(), "s2 evicted by s3's decode");
        let g = server.cache_gauges();
        assert_eq!(g.budget_pages, Some(6));
        assert!(g.pages_in_use <= 6);
        assert!(g.utilization() <= 1.0);
        server.shutdown();
    }

    /// An open that could never fit the pool — even with every other
    /// session evicted — is rejected up front and evicts nobody.
    #[test]
    fn infeasible_open_rejected_without_collateral_eviction() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.cache.page_elems = 3 * 2 * 16 * 8; // 8 rows/page at (h=2, d=16)
        cfg.cache.budget_pages = Some(6);
        let server = Server::start(cfg).unwrap();
        let (s1, t1) = server
            .open_session(mk_job(24, ModePreference::Exact, true, 1))
            .unwrap();
        t1.wait().unwrap();
        // 64 rows need 8 pages > the whole 6-page budget
        let (_, t2) = server
            .open_session(mk_job(64, ModePreference::Exact, true, 2))
            .unwrap();
        let err = t2.wait().unwrap_err();
        assert!(err.contains("admission rejected"), "{err}");
        let m = server.metrics();
        assert_eq!(m.sessions_evicted.load(Ordering::Relaxed), 0, "no collateral eviction");
        assert!(m.admission_rejects.load(Ordering::Relaxed) >= 1);
        // the existing session is untouched and still decodable
        let mut rng = Rng::new(3);
        let dj = DecodeJob {
            session: s1,
            heads: 2,
            d: 16,
            pos: None,
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        assert!(server.decode_wait(dj).is_ok());
        server.shutdown();
    }

    /// With nothing evictable, pool exhaustion is explicit backpressure
    /// on open, not a hang or a panic.
    #[test]
    fn page_budget_backpressure_when_nothing_evictable() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.cache.page_elems = 3 * 2 * 16 * 8;
        cfg.cache.budget_pages = Some(2); // below one session's 3 pages
        let server = Server::start(cfg).unwrap();
        let (_, ticket) = server
            .open_session(mk_job(24, ModePreference::Exact, true, 1))
            .unwrap();
        let err = ticket.wait().unwrap_err();
        assert!(err.contains("admission rejected"), "{err}");
        let m = server.metrics();
        assert!(m.admission_rejects.load(Ordering::Relaxed) >= 1);
        assert_eq!(server.cache_gauges().pages_in_use, 0, "failed open leaks nothing");
        server.shutdown();
    }

    /// The idle-session TTL sweep reclaims a session whose client
    /// dropped its handle without close_session.
    #[test]
    fn idle_session_ttl_sweep_reclaims() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.cache.idle_ttl = Some(Duration::from_millis(50));
        let server = Server::start(cfg).unwrap();
        let (sid, ticket) = server
            .open_session(mk_job(16, ModePreference::Exact, true, 1))
            .unwrap();
        ticket.wait().unwrap();
        assert_eq!(server.cache_gauges().per_session.len(), 1);
        // client "leaks" the session: no decode, no close
        std::thread::sleep(Duration::from_millis(400));
        let m = server.metrics();
        assert!(
            m.sessions_reclaimed.load(Ordering::Relaxed) >= 1,
            "sweep must have reclaimed the idle session"
        );
        assert_eq!(server.cache_gauges().per_session.len(), 0);
        assert_eq!(server.cache_gauges().pages_in_use, 0);
        let dj = DecodeJob {
            session: sid,
            heads: 2,
            d: 16,
            pos: None,
            q: vec![0.0; 32],
            k: vec![0.0; 32],
            v: vec![0.0; 32],
        };
        assert!(server.decode_wait(dj).is_err(), "reclaimed session is gone");
        server.shutdown();
    }

    /// The end-to-end sharing invariant: N sessions opened against a
    /// registered P-page prefix occupy P + N·(private tail) pages,
    /// `pages_shared` reports the shared prefix pages, closing N−1
    /// sessions frees nothing shared, and releasing the prefix plus the
    /// last session frees everything.
    #[test]
    fn prefix_sessions_share_pages_end_to_end() {
        let mut cfg = ServerConfig::substrate_only();
        // mk_job shape is (h=2, d=16): 8 rows per page
        cfg.cache.page_elems = 3 * 2 * 16 * 8;
        let server = Server::start(cfg).unwrap();
        // 20-row prefix: 2 full pages + a 4-row tail page
        let pre = server
            .register_prefix("sys", mk_job(20, ModePreference::Exact, true, 7))
            .unwrap();
        let out = pre.wait().unwrap();
        assert_eq!(out.out.len(), 2 * 20 * 16);
        assert_eq!(server.cache_gauges().pages_in_use, 3);

        // open 3 sessions, each continuing the prefix with 2 rows
        let n_sessions = 3usize;
        let mut sids = Vec::new();
        for s in 0..n_sessions {
            let (sid, t) = server
                .open_session_with_prefix(
                    Some("sys"),
                    mk_job(2, ModePreference::Exact, true, 100 + s as i32),
                )
                .unwrap();
            let resp = t.wait().unwrap();
            assert_eq!(resp.out.len(), 2 * 2 * 16, "suffix outputs only");
            sids.push(sid);
        }
        let g = server.cache_gauges();
        // P=3 prefix pages + one COW'd tail page per session
        assert_eq!(g.pages_in_use, 3 + n_sessions);
        assert_eq!(g.pages_shared, 2, "the two frozen prefix pages");
        assert_eq!(g.cow_copies, n_sessions as u64);
        assert_eq!(g.per_prefix, vec![("sys".to_string(), 3, 20)]);
        // sessions decode from position prefix+suffix onward
        let mut rng = Rng::new(9);
        let dj = DecodeJob {
            session: sids[0],
            heads: 2,
            d: 16,
            pos: Some(22),
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        let resp = server.decode_wait(dj).unwrap();
        assert_eq!(resp.pos, 22);
        // closing all but one session frees only private tails
        for &sid in &sids[..n_sessions - 1] {
            server.close_session(sid).unwrap();
        }
        // close is fire-and-forget: sync on a decode to the survivor
        let dj = DecodeJob {
            session: sids[n_sessions - 1],
            heads: 2,
            d: 16,
            pos: Some(22),
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        server.decode_wait(dj).unwrap();
        let g = server.cache_gauges();
        assert_eq!(g.pages_shared, 2, "closing forks must not free shared pages");
        // unknown prefix is an explicit error
        let (_, t) = server
            .open_session_with_prefix(Some("nope"), mk_job(2, ModePreference::Exact, true, 1))
            .unwrap();
        assert!(t.wait().unwrap_err().contains("unknown prefix"));
        // release the prefix and the last session: everything frees
        server.release_prefix("sys").unwrap();
        server.close_session(sids[n_sessions - 1]).unwrap();
        server.shutdown();
    }

    #[test]
    fn queue_latency_and_exec_recorded() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let resp = server
            .submit_wait(mk_job(64, ModePreference::Hyper, true, 3))
            .unwrap();
        assert!(resp.exec_us > 0);
        assert!(server.metrics().e2e_latency.count() == 1);
        server.shutdown();
    }

    /// The health probe answers through the full pipeline, and reports
    /// shutdown as an error instead of hanging.
    #[test]
    fn ping_probes_the_live_pipeline() {
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        server.ping(Duration::from_secs(10)).unwrap();
        // still healthy with real work in flight
        let t = server.submit(mk_job(64, ModePreference::Exact, false, 1)).unwrap();
        server.ping(Duration::from_secs(10)).unwrap();
        t.wait().unwrap();
        server.shutdown();
    }

    /// An already-expired explicit deadline resolves with
    /// `DEADLINE_EXPIRED` end to end, bumps the counter, and leaves the
    /// session cache untouched for a retry with a fresh deadline.
    #[test]
    fn expired_deadline_resolves_end_to_end() {
        use crate::coordinator::request::DEADLINE_EXPIRED;
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let (sid, t) = server
            .open_session(mk_job(16, ModePreference::Exact, true, 1))
            .unwrap();
        t.wait().unwrap();
        let mut rng = Rng::new(4);
        let mut dj = || DecodeJob {
            session: sid,
            heads: 2,
            d: 16,
            pos: None,
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        let late = server
            .decode_with_deadline(dj(), Instant::now() - Duration::from_millis(1))
            .unwrap();
        let err = late.wait().unwrap_err();
        assert!(err.contains(DEADLINE_EXPIRED), "{err}");
        assert_eq!(server.metrics().deadline_expired.load(Ordering::Relaxed), 1);
        // overload-accounting contract: the expired step's queued time
        // landed in the latency histograms (open + expired decode = 2)
        assert_eq!(server.metrics().e2e_latency.count(), 2, "expired step missing from e2e");
        assert_eq!(server.metrics().queue_latency.count(), 2, "expired step missing from queue");
        // the expired step never touched the cache: a position-checked
        // retry at the prompt length succeeds
        let mut retry = dj();
        retry.pos = Some(16);
        let resp = server
            .decode_with_deadline(retry, Instant::now() + Duration::from_secs(30))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.pos, 16);
        // a one-shot submit with an expired deadline expires too
        let err = server
            .submit_with_deadline(
                mk_job(32, ModePreference::Exact, false, 2),
                Instant::now() - Duration::from_millis(1),
            )
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(err.contains(DEADLINE_EXPIRED), "{err}");
        server.shutdown();
    }

    /// A server-wide `request_timeout` stamps every request: with a
    /// generous timeout everything completes; the deadline is a
    /// backstop, not a tax.
    #[test]
    fn request_timeout_default_is_harmless_when_generous() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.request_timeout = Some(Duration::from_secs(60));
        let server = Server::start(cfg).unwrap();
        let resp = server
            .submit_wait(mk_job(32, ModePreference::Exact, false, 1))
            .unwrap();
        assert!(resp.out.iter().all(|x| x.is_finite()));
        assert_eq!(server.metrics().deadline_expired.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    /// Shutdown under load **with failpoints firing**: every queued
    /// ticket resolves (Ok, injected error, or the shutdown flush —
    /// never a hang), pinned prefixes are released, and every page goes
    /// back to the pool.
    #[test]
    fn shutdown_under_load_with_failpoints_resolves_everything() {
        let _g = crate::coordinator::failpoint::test_lock::serial();
        crate::coordinator::failpoint::configure(
            "decode_job=err:0.3,kv_append=err:0.2,engine_recv=delay:1ms",
            7,
        )
        .unwrap();
        let cfg = ServerConfig::substrate_only();
        let server = Server::start(cfg).unwrap();
        let pre = server
            .register_prefix("sys", mk_job(24, ModePreference::Exact, true, 1))
            .unwrap();
        // the register itself may be hit by kv_append faults; a session
        // open against a failed register errors explicitly — both fine
        let registered = pre.wait().is_ok();
        let mut tickets = Vec::new();
        let mut rng = Rng::new(11);
        for s in 0..4 {
            let opened = if registered && s % 2 == 0 {
                server.open_session_with_prefix(
                    Some("sys"),
                    mk_job(4, ModePreference::Exact, true, 50 + s),
                )
            } else {
                server.open_session(mk_job(16, ModePreference::Exact, true, 50 + s))
            };
            let (sid, t) = opened.unwrap();
            let _ = t.wait(); // Ok or injected error, never a hang
            for _ in 0..4 {
                let dj = DecodeJob {
                    session: sid,
                    heads: 2,
                    d: 16,
                    pos: None,
                    q: rng.normal_vec(32),
                    k: rng.normal_vec(32),
                    v: rng.normal_vec(32),
                };
                tickets.push(server.decode(dj).unwrap());
            }
        }
        server.release_prefix("sys").unwrap();
        let pool = server.pool.clone();
        drop(server); // shutdown via Drop, with decode steps still queued
        crate::coordinator::failpoint::clear();
        for t in tickets {
            // every ticket resolves: success, injected fault, or the
            // explicit shutdown-flush error
            t.wait_timeout(Duration::from_secs(10)).ok();
        }
        // the shutdown drain released every session and the pinned
        // prefix: no page frame leaked, conservation holds
        let s = pool.stats();
        assert_eq!(s.outstanding, 0, "pages leaked through shutdown: {s:?}");
        assert_eq!(s.outstanding + s.free, (s.allocs - s.reuses) as usize);
    }

    /// A long causal open (and a long causal one-shot) rerouted through
    /// the scheduler's chunked-ingest path returns the same output as
    /// the monolithic path, and the session decodes seamlessly after.
    #[test]
    fn chunked_open_matches_monolithic_and_decodes() {
        let n = 72usize;
        let job = || mk_job(n, ModePreference::Exact, true, 21);
        let mono = Server::start(ServerConfig::substrate_only()).unwrap();
        let (_, t) = mono.open_session(job()).unwrap();
        let want = t.wait().unwrap().out;
        mono.shutdown();

        let mut cfg = ServerConfig::substrate_only();
        cfg.sched.prefill_chunk = 16; // 72 rows -> 5 chunks
        let server = Server::start(cfg).unwrap();
        let (sid, t) = server.open_session(job()).unwrap();
        let got = t.wait().unwrap().out;
        assert_eq!(got.len(), want.len());
        let max = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-4, "chunked vs monolithic prefill diff {max}");
        // a one-shot Full job takes the same chunked path and agrees too
        let full = server.submit_wait(job()).unwrap();
        let max = full.out.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-4, "chunked full vs monolithic diff {max}");
        let m = server.metrics();
        assert_eq!(m.chunked_ingests.load(Ordering::Relaxed), 2);
        assert_eq!(m.prefill_chunks.load(Ordering::Relaxed), 10);
        assert_eq!(m.sessions_opened.load(Ordering::Relaxed), 1);
        assert_eq!(m.ingest_serial_fallbacks.load(Ordering::Relaxed), 0);
        // the session is live at the full prompt position
        let mut rng = Rng::new(3);
        let dj = DecodeJob {
            session: sid,
            heads: 2,
            d: 16,
            pos: Some(n),
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        let resp = server.decode_wait(dj).unwrap();
        assert_eq!(resp.pos, n);
        let g = server.cache_gauges();
        assert_eq!(g.chunked_ingests, 2);
        assert_eq!(g.prefill_chunks, 10);
        server.shutdown();
    }

    /// Tokens keep flowing while a long prompt streams in: with each
    /// chunk slowed by an injected delay, decode steps for a live
    /// session complete BEFORE the big open resolves — the occupancy-
    /// under-ingest property the chunked path exists for.
    #[test]
    fn decode_keeps_flowing_during_chunked_ingest() {
        let _g = crate::coordinator::failpoint::test_lock::serial();
        crate::coordinator::failpoint::configure("prefill_chunk=delay:2ms", 1).unwrap();
        let mut cfg = ServerConfig::substrate_only();
        cfg.sched.prefill_chunk = 4;
        let server = Server::start(cfg).unwrap();
        // a short session first (n == chunk: stays monolithic)
        let (sid, t) = server
            .open_session(mk_job(4, ModePreference::Exact, true, 1))
            .unwrap();
        t.wait().unwrap();
        // the long open streams in 4-row chunks, each >= 2ms
        let (_, t_big) = server
            .open_session(mk_job(240, ModePreference::Exact, true, 2))
            .unwrap();
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let waiter = {
            let done = done.clone();
            std::thread::spawn(move || {
                let r = t_big.wait();
                done.store(true, Ordering::SeqCst);
                r
            })
        };
        let mut rng = Rng::new(9);
        let mut decoded_during = 0usize;
        while !done.load(Ordering::SeqCst) {
            let dj = DecodeJob {
                session: sid,
                heads: 2,
                d: 16,
                pos: None,
                q: rng.normal_vec(32),
                k: rng.normal_vec(32),
                v: rng.normal_vec(32),
            };
            if server.decode_wait(dj).is_ok() {
                decoded_during += 1;
            }
        }
        waiter.join().unwrap().unwrap();
        crate::coordinator::failpoint::clear();
        assert!(decoded_during > 0, "decode lane starved during the long ingest");
        let m = server.metrics();
        assert_eq!(m.chunked_ingests.load(Ordering::Relaxed), 1);
        assert_eq!(m.prefill_chunks.load(Ordering::Relaxed), 60);
        server.shutdown();
    }

    /// A windowed (sink-less) session can now open a prompt much longer
    /// than its window: the coordinator chunks the ingest and clamps
    /// each appended chunk to the window, so no chunk trips the op's
    /// "would evict its own oldest queries" guard.
    #[test]
    fn windowed_open_of_long_prompt_succeeds_via_chunking() {
        use crate::attention::op::CachePolicy;
        let mut cfg = ServerConfig::substrate_only();
        cfg.cache.page_elems = 3 * 2 * 16 * 8; // 8 rows/page at (h=2, d=16)
        cfg.cache.policy = CachePolicy::SlidingWindow { window: 16, sink: 0 };
        cfg.sched.prefill_chunk = 24; // > window: exercises the per-chunk clamp
        let server = Server::start(cfg).unwrap();
        let n = 96usize;
        let (sid, t) = server
            .open_session(mk_job(n, ModePreference::Exact, true, 5))
            .unwrap();
        let resp = t.wait().unwrap();
        assert_eq!(resp.out.len(), 2 * n * 16);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        // decode continues at the full logical position
        let mut rng = Rng::new(6);
        let dj = DecodeJob {
            session: sid,
            heads: 2,
            d: 16,
            pos: Some(n),
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        assert_eq!(server.decode_wait(dj).unwrap().pos, n);
        server.shutdown();
    }

    /// Failpoints are configuration, not code: the same binary with the
    /// spec cleared behaves identically to one that never armed them.
    #[test]
    fn cleared_failpoints_leave_no_residue() {
        let _g = crate::coordinator::failpoint::test_lock::serial();
        crate::coordinator::failpoint::configure("decode_job=err:1.0", 3).unwrap();
        crate::coordinator::failpoint::clear();
        let server = Server::start(ServerConfig::substrate_only()).unwrap();
        let (sid, t) = server
            .open_session(mk_job(16, ModePreference::Exact, true, 1))
            .unwrap();
        t.wait().unwrap();
        let mut rng = Rng::new(2);
        let dj = DecodeJob {
            session: sid,
            heads: 2,
            d: 16,
            pos: None,
            q: rng.normal_vec(32),
            k: rng.normal_vec(32),
            v: rng.normal_vec(32),
        };
        server.decode_wait(dj).unwrap();
        server.shutdown();
    }
}

//! Server wiring: submit → route → dynamic batch → engine → response.
//!
//! Pure `std::thread` + channels (no async runtime in this tree): a
//! batcher thread hosts the [`BatchQueue`] state machine, flushing on
//! size or deadline via `recv_timeout`; the engine thread hosts PJRT +
//! the Rust substrate.  Backpressure: both channels are bounded, so a
//! full pipeline pushes back on `submit()`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::batcher::{BatchConfig, BatchQueue};
use super::engine::{self, EngineMsg, WorkItem};
use super::metrics::Metrics;
use super::request::{AttnJob, AttnResponse};
use super::router::{Route, Router, RouterConfig};
use crate::runtime::Manifest;

/// Full coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub router: RouterConfig,
    pub batch: BatchConfig,
    /// directory with manifest.json + *.hlo.txt; None = substrate only
    pub artifacts_dir: Option<PathBuf>,
    /// bounded queue depths (submit channel & engine channel)
    pub queue_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            router: RouterConfig::default(),
            batch: BatchConfig::default(),
            artifacts_dir: None,
            queue_depth: 256,
        }
    }
}

impl ServerConfig {
    pub fn substrate_only() -> Self {
        ServerConfig::default()
    }

    pub fn with_artifacts(dir: impl Into<PathBuf>) -> Self {
        ServerConfig { artifacts_dir: Some(dir.into()), ..Default::default() }
    }
}

struct Submission {
    job: AttnJob,
    respond: SyncSender<Result<AttnResponse, String>>,
    submitted: Instant,
}

/// A pending response handle (await with [`Ticket::wait`]).
pub struct Ticket {
    rx: Receiver<Result<AttnResponse, String>>,
}

impl Ticket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<AttnResponse, String> {
        self.rx
            .recv()
            .map_err(|_| "engine dropped job".to_string())?
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, dur: Duration) -> Result<AttnResponse, String> {
        match self.rx.recv_timeout(dur) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err("timed out".into()),
            Err(RecvTimeoutError::Disconnected) => Err("engine dropped job".into()),
        }
    }
}

/// Handle to a running coordinator.
pub struct Server {
    submit_tx: Option<SyncSender<Submission>>,
    metrics: Arc<Metrics>,
    engine_handle: Option<std::thread::JoinHandle<()>>,
    batcher_handle: Option<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Server {
    /// Start the coordinator (spawns the batcher + engine threads).
    pub fn start(config: ServerConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let depth = config.queue_depth.max(1);

        // Router reads the manifest here; the engine re-opens the runtime
        // on its own thread (PjRtClient is thread-affine).
        let manifest = config
            .artifacts_dir
            .as_ref()
            .and_then(|d| Manifest::load(d.join("manifest.json")).ok());
        let router = Router::new(config.router.clone(), manifest.as_ref());

        let (engine_tx, engine_handle) = engine::spawn(
            config.artifacts_dir.clone(),
            config.router.clone(),
            metrics.clone(),
            depth,
        );

        let (submit_tx, submit_rx) = sync_channel::<Submission>(depth);
        let batch_cfg = config.batch;

        let batcher_handle = std::thread::Builder::new()
            .name("hyperattn-batcher".into())
            .spawn(move || {
                let mut queue: BatchQueue<Route, WorkItem> = BatchQueue::new(batch_cfg);
                loop {
                    // Wait for the next submission or the flush deadline.
                    let msg = match queue.next_deadline() {
                        Some(deadline) => {
                            let now = Instant::now();
                            if deadline <= now {
                                // deadline already passed: flush, don't block
                                for (_, batch) in queue.tick(now) {
                                    if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                        return;
                                    }
                                }
                                continue;
                            }
                            match submit_rx.recv_timeout(deadline - now) {
                                Ok(s) => Some(s),
                                Err(RecvTimeoutError::Timeout) => None,
                                Err(RecvTimeoutError::Disconnected) => break,
                            }
                        }
                        None => match submit_rx.recv() {
                            Ok(s) => Some(s),
                            Err(_) => break,
                        },
                    };
                    match msg {
                        Some(sub) => {
                            let route = router.route(&sub.job);
                            let item = WorkItem {
                                job: sub.job,
                                route: route.clone(),
                                submitted: sub.submitted,
                                respond: sub.respond,
                            };
                            if let Some((_, batch)) = queue.push(route, item, Instant::now()) {
                                if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                    return;
                                }
                            }
                        }
                        None => {
                            for (_, batch) in queue.tick(Instant::now()) {
                                if engine_tx.send(EngineMsg::Batch(batch)).is_err() {
                                    return;
                                }
                            }
                        }
                    }
                }
                // channel closed: drain and stop the engine
                for (_, batch) in queue.drain() {
                    let _ = engine_tx.send(EngineMsg::Batch(batch));
                }
                let _ = engine_tx.send(EngineMsg::Shutdown);
            })
            .expect("spawn batcher thread");

        Server {
            submit_tx: Some(submit_tx),
            metrics,
            engine_handle: Some(engine_handle),
            batcher_handle: Some(batcher_handle),
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit a job; returns a [`Ticket`] to wait on.  Blocks only if the
    /// submit queue is full (backpressure).
    pub fn submit(&self, mut job: AttnJob) -> Result<Ticket, String> {
        job.validate()?;
        if job.id == 0 {
            job.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.submit_tx
            .as_ref()
            .expect("server running")
            .send(Submission { job, respond: tx, submitted: Instant::now() })
            .map_err(|_| "coordinator shut down".to_string())?;
        Ok(Ticket { rx })
    }

    /// Submit and block until completion.
    pub fn submit_wait(&self, job: AttnJob) -> Result<AttnResponse, String> {
        self.submit(job)?.wait()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: drain queues, stop both threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Closing the submit channel makes the batcher drain + stop, which
        // in turn shuts the engine down.
        self.submit_tx.take();
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Backend, ModePreference};
    use crate::rng::Rng;

    fn mk_job(n: usize, mode: ModePreference, causal: bool, seed: i32) -> AttnJob {
        let (h, d) = (2, 16);
        let mut rng = Rng::new(seed as u64);
        AttnJob {
            id: 0,
            heads: h,
            n,
            d,
            q: rng.normal_vec(h * n * d),
            k: rng.normal_vec(h * n * d),
            v: rng.normal_vec(h * n * d),
            causal,
            mode,
            seed,
        }
    }

    #[test]
    fn substrate_roundtrip() {
        let server = Server::start(ServerConfig::substrate_only());
        let resp = server
            .submit_wait(mk_job(32, ModePreference::Exact, false, 1))
            .unwrap();
        assert_eq!(resp.out.len(), 2 * 32 * 16);
        assert_eq!(resp.backend, Backend::Substrate);
        assert!(resp.out.iter().all(|x| x.is_finite()));
        server.shutdown();
    }

    #[test]
    fn concurrent_jobs_all_complete() {
        let server = Arc::new(Server::start(ServerConfig::substrate_only()));
        let mut handles = Vec::new();
        for i in 0..24 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let mode = if i % 2 == 0 {
                    ModePreference::Exact
                } else {
                    ModePreference::Hyper
                };
                s.submit_wait(mk_job(64, mode, i % 3 == 0, i))
            }));
        }
        for h in handles {
            let resp = h.join().unwrap().unwrap();
            assert!(resp.out.iter().all(|x| x.is_finite()));
        }
        let m = server.metrics();
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 24);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn invalid_job_rejected_before_queue() {
        let server = Server::start(ServerConfig::substrate_only());
        let mut j = mk_job(16, ModePreference::Exact, false, 0);
        j.q.pop();
        assert!(server.submit(j).is_err());
        assert_eq!(server.metrics().jobs_submitted.load(Ordering::Relaxed), 0);
        server.shutdown();
    }

    #[test]
    fn batching_accumulates() {
        let mut cfg = ServerConfig::substrate_only();
        cfg.batch.max_batch = 4;
        cfg.batch.max_wait = Duration::from_millis(50);
        let server = Arc::new(Server::start(cfg));
        let mut handles = Vec::new();
        for i in 0..8 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                s.submit_wait(mk_job(32, ModePreference::Exact, false, i))
            }));
        }
        for h in handles {
            h.join().unwrap().unwrap();
        }
        // 8 same-route jobs with max_batch 4: mean batch size must beat 1
        assert!(server.metrics().mean_batch_size() > 1.0);
    }

    #[test]
    fn queue_latency_and_exec_recorded() {
        let server = Server::start(ServerConfig::substrate_only());
        let resp = server
            .submit_wait(mk_job(64, ModePreference::Hyper, true, 3))
            .unwrap();
        assert!(resp.exec_us > 0);
        assert!(server.metrics().e2e_latency.count() == 1);
        server.shutdown();
    }
}

//! Data-parallel substrate: scoped-thread fork/join with dynamic work
//! stealing, built on `std::thread` only (no rayon in this tree — every
//! substrate is built from scratch).
//!
//! The primitives mirror the three shapes the attention kernels need:
//! * [`par_for`] — dynamic index-parallel loop (atomic-counter stealing);
//! * [`par_rows`] — parallel over disjoint row slices of one flat buffer
//!   (the matmul/attention output pattern);
//! * [`par_map`] — collect per-index results into a Vec.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Runtime override set by [`set_threads`] (0 = none).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads: [`set_threads`] override if set, else the
/// `HYPERATTN_THREADS` env var, else `available_parallelism` (cached).
pub fn num_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("HYPERATTN_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4)
            })
    })
}

/// Force the worker-thread count at runtime (`0` clears the override and
/// returns to the env/default behaviour).  Used by the single-thread
/// perf-gate bench; takes effect for every later `par_*` call.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Dynamic parallel `for i in 0..n`, grain-batched atomic stealing.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = num_threads().min(n);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let grain = (n / (threads * 8)).max(1);
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let start = counter.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Parallel over the `rows` disjoint `cols`-sized slices of `data`:
/// `f(row_index, row_slice)`.  This is the safe replacement for the
/// "raw-pointer disjoint tile" pattern.
pub fn par_rows<F: Fn(usize, &mut [f32]) + Sync>(data: &mut [f32], cols: usize, f: F) {
    assert!(cols > 0 && data.len() % cols == 0);
    let n = data.len() / cols;
    let ptr = data.as_mut_ptr() as usize;
    par_for(n, |i| {
        // SAFETY: par_for hands out each index exactly once; rows are
        // disjoint cols-sized slices of `data`.
        let row = unsafe { std::slice::from_raw_parts_mut((ptr as *mut f32).add(i * cols), cols) };
        f(i, row);
    });
}

/// Parallel over contiguous blocks of `rows_per_block` rows of `data`
/// (the last block may be short): `f(first_row, block_slice)`.  The
/// multi-row analogue of [`par_rows`], used by the panel GEMM callers.
pub fn par_row_blocks<F: Fn(usize, &mut [f32]) + Sync>(
    data: &mut [f32],
    cols: usize,
    rows_per_block: usize,
    f: F,
) {
    assert!(cols > 0 && data.len() % cols == 0 && rows_per_block > 0);
    let n = data.len() / cols;
    let nb = n.div_ceil(rows_per_block);
    let ptr = data.as_mut_ptr() as usize;
    par_for(nb, |bi| {
        let r0 = bi * rows_per_block;
        let r1 = ((bi + 1) * rows_per_block).min(n);
        // SAFETY: par_for hands out each block index exactly once; blocks
        // are disjoint row ranges of `data`.
        let block = unsafe {
            std::slice::from_raw_parts_mut((ptr as *mut f32).add(r0 * cols), (r1 - r0) * cols)
        };
        f(r0, block);
    });
}

/// Parallel map: `out[i] = f(i)`.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let ptr = out.as_mut_ptr() as usize;
    par_for(n, |i| {
        // SAFETY: each index written exactly once, Option<T> slot is
        // pre-initialized to None and replaced wholesale.
        unsafe {
            *(ptr as *mut Option<T>).add(i) = Some(f(i));
        }
    });
    out.into_iter().map(|x| x.expect("all slots filled")).collect()
}

/// Parallel fold-max over f(i) (for τ estimation and norms).
pub fn par_max<F: Fn(usize) -> f32 + Sync>(n: usize, f: F) -> f32 {
    use std::sync::Mutex;
    let best = Mutex::new(f32::NEG_INFINITY);
    let threads = num_threads().min(n.max(1));
    let counter = AtomicUsize::new(0);
    if n == 0 {
        return f32::NEG_INFINITY;
    }
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut local = f32::NEG_INFINITY;
                loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local = local.max(f(i));
                }
                let mut b = best.lock().unwrap();
                *b = b.max(local);
            });
        }
    });
    best.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_rows_disjoint_writes() {
        let mut data = vec![0.0f32; 64 * 8];
        par_rows(&mut data, 8, |i, row| {
            for (j, x) in row.iter_mut().enumerate() {
                *x = (i * 8 + j) as f32;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn par_map_ordered() {
        let v = par_map(257, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn par_max_correct() {
        let m = par_max(1000, |i| ((i as f32) - 500.0).sin() * (i as f32));
        let want = (0..1000)
            .map(|i| ((i as f32) - 500.0).sin() * (i as f32))
            .fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(m, want);
    }

    #[test]
    fn par_row_blocks_covers_all_rows() {
        let mut data = vec![0.0f32; 37 * 5]; // 37 rows: last block short
        par_row_blocks(&mut data, 5, 8, |r0, block| {
            for (r, row) in block.chunks_mut(5).enumerate() {
                for (c, x) in row.iter_mut().enumerate() {
                    *x = ((r0 + r) * 5 + c) as f32;
                }
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn empty_and_single() {
        par_for(0, |_| panic!("must not run"));
        let v = par_map(1, |i| i + 7);
        assert_eq!(v, vec![7]);
    }
}

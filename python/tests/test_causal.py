"""Algorithm 4 (recursive causal HyperAttention) correctness."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import causal, ref
from .conftest import clustered_qkv, rand_qkv


def test_base_case_is_exact_causal():
    """n <= base short-circuits to the exact causal flash kernel."""
    q, k, v = rand_qkv(31, 64, 16)
    out = causal.causal_hyper_attention(q, k, v, 0, base=64, block=16,
                                        n_samples=16)
    exp = ref.attention_exact(q, k, v, causal=True)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_one_level_recursion_structure():
    """With one split, the first half must be EXACT causal attention (it
    recurses straight into the base case), independent of sampling."""
    n = 128
    q, k, v = rand_qkv(32, n, 16)
    out = causal.causal_hyper_attention(q, k, v, 3, base=64, block=16,
                                        n_samples=16)
    exp = ref.attention_exact(q, k, v, causal=True)
    assert_allclose(np.asarray(out[: n // 2]), np.asarray(exp[: n // 2]),
                    atol=2e-5, rtol=2e-5)


def test_causal_never_attends_future():
    """Make future values NaN-poison: output must stay finite, because a
    causal estimator never touches keys/values above the diagonal...
    except that position i may only use v[<=i]."""
    n = 128
    q, k, v = rand_qkv(33, n, 8)
    # Poison the last quarter of V; rows < n/2 must be unaffected vs
    # the clean run (they can never sample from the second half).
    v_bad = v.at[3 * n // 4:].set(jnp.nan)
    out_clean = causal.causal_hyper_attention(q, k, v, 1, base=32, block=16,
                                              n_samples=16)
    out_bad = causal.causal_hyper_attention(q, k, v_bad, 1, base=32, block=16,
                                            n_samples=16)
    assert_allclose(np.asarray(out_bad[: n // 2]),
                    np.asarray(out_clean[: n // 2]), atol=1e-6)


def test_causal_accuracy_on_clustered():
    q, k, v = clustered_qkv(34, 256, 32)
    out = causal.causal_hyper_attention(q, k, v, 7, base=64, block=32,
                                        n_samples=128)
    exp = ref.attention_exact(q, k, v, causal=True)
    # first half exact-by-construction + approximate second half
    rel = float(jnp.linalg.norm(out - exp) / jnp.linalg.norm(exp))
    assert rel < 0.6, f"rel error {rel}"


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([128, 256]), d=st.sampled_from([8, 16]),
       base=st.sampled_from([32, 64]), seed=st.integers(0, 500))
def test_causal_hypothesis_finite(n, d, base, seed):
    q, k, v = rand_qkv(seed, n, d)
    out = causal.causal_hyper_attention(q, k, v, seed, base=base, block=16,
                                        n_samples=16)
    assert out.shape == (n, d)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_causal_multihead_shapes():
    q, k, v = rand_qkv(35, 128, 16)
    qh = jnp.stack([q] * 3)
    out = causal.causal_hyper_attention_mh(qh, qh, qh, 0, base=64, block=16,
                                           n_samples=16)
    assert out.shape == (3, 128, 16)


def test_concat_parts_roundtrip():
    q, k, v = rand_qkv(36, 64, 8)
    p = ref.attention_parts_exact(q, k, v, causal=True)
    p1 = (p[0][:32], p[1][:32], p[2][:32])
    p2 = (p[0][32:], p[1][32:], p[2][32:])
    m, s, num = causal._concat_parts(p1, p2)
    assert_allclose(np.asarray(m), np.asarray(p[0]))
    assert_allclose(np.asarray(s), np.asarray(p[1]))
    assert_allclose(np.asarray(num), np.asarray(p[2]))

"""Shared fixtures and input generators for the kernel test suite."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest


def rand_qkv(seed: int, n: int, d: int, scale: float = 1.0):
    """Unstructured gaussian Q, K, V."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (scale * jax.random.normal(kq, (n, d)),
            scale * jax.random.normal(kk, (n, d)),
            jax.random.normal(kv, (n, d)))


def clustered_qkv(seed: int, n: int, d: int, n_clusters: int = 8,
                  spread: float = 0.25, center_scale: float = 2.0):
    """LSH-friendly inputs: queries/keys drawn around shared cluster centers.

    This is the regime the paper's assumptions target: attention mass is
    concentrated on same-cluster (large-entry) pairs, which sortLSH maps
    into diagonal blocks.
    """
    kc, kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 4)
    centers = center_scale * jax.random.normal(kc, (n_clusters, d))
    assign = jnp.arange(n) % n_clusters
    q = centers[assign] + spread * jax.random.normal(kq, (n, d))
    k = centers[assign] + spread * jax.random.normal(kk, (n, d))
    v = jax.random.normal(kv, (n, d))
    return q, k, v


@pytest.fixture(scope="session")
def small_qkv():
    return rand_qkv(0, 128, 32)


@pytest.fixture(scope="session")
def clustered():
    return clustered_qkv(1, 256, 32)

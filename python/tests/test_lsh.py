"""Hamming-sorted LSH: Gray-code properties and collision statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lsh


def test_gray_to_binary_roundtrip():
    """Gray decode of the standard Gray sequence is 0,1,2,..."""
    r = 6
    n = 2 ** r
    binary = np.array([[(i >> (r - 1 - b)) & 1 for b in range(r)]
                       for i in range(n)])
    gray = np.array([[((i ^ (i >> 1)) >> (r - 1 - b)) & 1 for b in range(r)]
                     for i in range(n)])
    dec = np.asarray(lsh.gray_to_binary(jnp.asarray(gray)))
    assert np.array_equal(dec, binary)


def test_adjacent_buckets_hamming_one():
    """Consecutive bucket ids must correspond to sign patterns at Hamming
    distance exactly 1 (the 'Hamming sorted' property of Definition 1)."""
    r = 8
    n = 2 ** r
    gray = np.array([[((i ^ (i >> 1)) >> (r - 1 - b)) & 1 for b in range(r)]
                     for i in range(n)])
    for i in range(n - 1):
        assert np.sum(gray[i] != gray[i + 1]) == 1


def test_bucket_ids_range_and_determinism():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 16))
    proj = lsh.projections(jax.random.PRNGKey(1), 16, 8)
    b1 = np.asarray(lsh.bucket_ids(x, proj))
    b2 = np.asarray(lsh.bucket_ids(x, proj))
    assert np.array_equal(b1, b2)
    assert b1.min() >= 0 and b1.max() < 2 ** 8


def test_identical_points_collide():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    proj = lsh.projections(jax.random.PRNGKey(3), 16, 10)
    b = lsh.bucket_ids(jnp.concatenate([x, x]), proj)
    assert np.array_equal(np.asarray(b[:32]), np.asarray(b[32:]))


def test_collision_probability_formula_montecarlo():
    """Empirical collisions over random projections match Definition 1."""
    d, r, trials = 8, 4, 400
    theta = 0.3
    x = jnp.zeros(d).at[0].set(1.0)
    y = jnp.zeros(d).at[0].set(jnp.cos(theta)).at[1].set(jnp.sin(theta))
    hits = 0
    for t in range(trials):
        proj = lsh.projections(jax.random.PRNGKey(t), d, r)
        bx = lsh.bucket_ids(x[None, :], proj)
        by = lsh.bucket_ids(y[None, :], proj)
        hits += int(bx[0] == by[0])
    expected = float(lsh.collision_probability(theta, r))
    assert abs(hits / trials - expected) < 0.08


def test_sort_permutation_is_permutation():
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 8))
    proj = lsh.projections(jax.random.PRNGKey(5), 8, 6)
    perm, buckets = lsh.sort_permutation(x, proj)
    perm = np.asarray(perm)
    assert sorted(perm.tolist()) == list(range(128))
    sorted_buckets = np.asarray(buckets)[perm]
    assert np.all(np.diff(sorted_buckets) >= 0)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([32, 64, 128]), d=st.sampled_from([4, 8, 16]),
       r=st.integers(2, 10), seed=st.integers(0, 2**16))
def test_sort_permutation_hypothesis(n, d, r, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    proj = lsh.projections(jax.random.PRNGKey(seed + 1), d, r)
    perm, _ = lsh.sort_permutation(x, proj)
    assert sorted(np.asarray(perm).tolist()) == list(range(n))


def test_block_mask_dense_structure():
    """Mask rows/cols must each contain exactly `block` ones."""
    n, b = 64, 16
    x = jax.random.normal(jax.random.PRNGKey(6), (n, 8))
    y = jax.random.normal(jax.random.PRNGKey(7), (n, 8))
    proj = lsh.projections(jax.random.PRNGKey(8), 8, 6)
    pq, _ = lsh.sort_permutation(x, proj)
    pk, _ = lsh.sort_permutation(y, proj)
    mask = np.asarray(lsh.block_mask_dense(pq, pk, n, b))
    assert mask.shape == (n, n)
    assert np.allclose(mask.sum(axis=1), b)
    assert np.allclose(mask.sum(axis=0), b)
    # nnz = n * b — the paper's sparse-by-design n^{1+o(1)} mask
    assert mask.sum() == n * b


def test_clustered_inputs_concentrate_in_blocks():
    """On clustered inputs the mask should capture most attention mass."""
    from .conftest import clustered_qkv
    from compile.kernels import ref

    q, k, _ = clustered_qkv(9, 256, 16, n_clusters=4, spread=0.1)
    proj = lsh.projections(jax.random.PRNGKey(10), 16, 8)
    pq, _ = lsh.sort_permutation(q, proj)
    pk, _ = lsh.sort_permutation(k, proj)
    mask = lsh.block_mask_dense(pq, pk, 256, 64)
    p = ref.softmax_matrix(q, k)
    captured = float(jnp.sum(mask * p) / 256)
    # random blocks would capture 0.25 of the mass; LSH should beat that
    assert captured > 0.5, f"captured only {captured:.3f}"

"""L2 model: shapes, determinism, patching semantics, loss/grad sanity."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod


CFG = model_mod.ModelConfig(
    vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64, max_seq=128,
    hyper_block=16, hyper_samples=16, hyper_base=32)


@pytest.fixture(scope="module")
def params():
    return model_mod.init_params(CFG, seed=0)


def _tokens(seed, n):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, CFG.vocab)


def test_forward_shape(params):
    toks = _tokens(0, 64)
    logits = model_mod.forward(CFG, params, toks)
    assert logits.shape == (64, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_deterministic(params):
    toks = _tokens(1, 64)
    a = model_mod.forward(CFG, params, toks, n_patched=2, seed=5)
    b = model_mod.forward(CFG, params, toks, n_patched=2, seed=5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_init_deterministic():
    p1 = model_mod.init_params(CFG, seed=3)
    p2 = model_mod.init_params(CFG, seed=3)
    np.testing.assert_allclose(np.asarray(p1["tok_emb"]),
                               np.asarray(p2["tok_emb"]))
    np.testing.assert_allclose(np.asarray(p1["layers"][1]["wqkv"]),
                               np.asarray(p2["layers"][1]["wqkv"]))


def test_patching_changes_output(params):
    toks = _tokens(2, 128)  # > hyper_base so hyper actually engages
    exact = model_mod.forward(CFG, params, toks, n_patched=0)
    patched = model_mod.forward(CFG, params, toks, n_patched=2)
    assert not np.allclose(np.asarray(exact), np.asarray(patched))


def test_patching_zero_equals_exact(params):
    toks = _tokens(3, 64)
    a = model_mod.forward(CFG, params, toks, n_patched=0)
    b = model_mod.forward(CFG, params, toks, n_patched=0, seed=999)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_short_sequence_never_hyper(params):
    """n <= hyper_base: patched layers silently fall back to exact."""
    toks = _tokens(4, 32)
    a = model_mod.forward(CFG, params, toks, n_patched=2, seed=1)
    b = model_mod.forward(CFG, params, toks, n_patched=2, seed=2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_loss_positive_and_reasonable(params):
    toks = _tokens(5, 64)
    loss = float(model_mod.loss_fn(CFG, params, toks))
    # random init => loss near ln(vocab)
    assert 0.5 * np.log(CFG.vocab) < loss < 2.5 * np.log(CFG.vocab)


def test_perplexity_monotone_in_patching(params):
    """More patched layers must not make a random-init model *better* on
    average (weak sanity: ppl(patched) within a sane band of ppl(exact))."""
    toks = _tokens(6, 128)
    p0 = float(model_mod.perplexity(CFG, params, toks, n_patched=0))
    p2 = float(model_mod.perplexity(CFG, params, toks, n_patched=2))
    assert p2 > 0.5 * p0


def test_grad_flows(params):
    toks = _tokens(7, 64)

    def loss_of_emb(emb):
        p = dict(params)
        p["tok_emb"] = emb
        # jnp attention: interpret-mode pallas_call has no VJP
        return model_mod.loss_fn(CFG, p, toks, attn_impl="jnp")

    g = jax.grad(loss_of_emb)(params["tok_emb"])
    assert bool(jnp.any(jnp.abs(g) > 0))
    assert bool(jnp.all(jnp.isfinite(g)))


def test_layer_norm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 32)) * 5 + 3
    y = model_mod.layer_norm(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1, atol=1e-2)

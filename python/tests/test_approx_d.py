"""Algorithm 2 (ApproxD) vs exact D, and the Lemma 1 / Eq. (2) bound."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import approx_d, lsh, ref
from .conftest import clustered_qkv, rand_qkv


def _mask_for(q, k, block):
    proj = lsh.projections(jax.random.PRNGKey(99), q.shape[1], 8)
    pq, _ = lsh.sort_permutation(q, proj)
    pk, _ = lsh.sort_permutation(k, proj)
    return lsh.block_mask_dense(pq, pk, q.shape[0], block)


def test_masked_row_sums_exact():
    q, k, _ = rand_qkv(41, 64, 16)
    mask = _mask_for(q, k, 16)
    got = np.asarray(approx_d.masked_row_sums(q, k, mask))
    sc = ref.softmax_scale(16)
    a = np.exp(np.asarray(q @ k.T) * sc)
    np.testing.assert_allclose(got, (np.asarray(mask) * a).sum(-1), rtol=1e-5)


def test_approx_d_with_full_sampling_tight():
    """m = n with uniform columns: estimate concentrates around exact D."""
    n = 128
    q, k, _ = rand_qkv(42, n, 16)
    mask = _mask_for(q, k, 32)
    ds = [approx_d.approx_d(jax.random.PRNGKey(s), q, k, mask,
                            kappa=4.0, eps=1.0, m=n)
          for s in range(8)]
    dt = np.mean(np.stack([np.asarray(x) for x in ds]), axis=0)
    de = np.asarray(ref.row_sums_exact(q, k))
    rel = np.abs(dt - de) / de
    assert np.median(rel) < 0.25, f"median rel {np.median(rel)}"


def test_approx_d_error_decreases_with_m():
    q, k, _ = clustered_qkv(43, 256, 16)
    mask = _mask_for(q, k, 64)
    errs = []
    for m in [32, 128, 512]:
        es = [float(approx_d.approx_d_error(
            approx_d.approx_d(jax.random.PRNGKey(s), q, k, mask,
                              kappa=8.0, eps=1.0, m=m), q, k))
            for s in range(3)]
        errs.append(np.mean(es))
    assert errs[2] < errs[0], f"not decreasing: {errs}"


def test_approx_d_lower_cap_positive():
    """d~ must be strictly positive (lower capping at tau/kappa)."""
    q, k, _ = rand_qkv(44, 64, 8)
    mask = jnp.zeros((64, 64))  # no mask at all
    dt = np.asarray(approx_d.approx_d(jax.random.PRNGKey(0), q, k, mask,
                                      kappa=2.0, eps=0.5, m=8))
    assert np.all(dt > 0)


def test_approx_d_includes_masked_part_exactly():
    """With kappa huge and m tiny, d~ ~= masked row sums (+ tiny floor):
    the masked contribution enters exactly, never estimated."""
    q, k, _ = clustered_qkv(45, 128, 16, spread=0.05)
    mask = _mask_for(q, k, 64)
    dt = np.asarray(approx_d.approx_d(jax.random.PRNGKey(1), q, k, mask,
                                      kappa=1e9, eps=1e-3, m=4))
    masked = np.asarray(approx_d.masked_row_sums(q, k, mask))
    assert np.all(dt >= masked - 1e-5)


def test_kappa_param_definition():
    q, k, _ = rand_qkv(46, 32, 8)
    mask = jnp.zeros((32, 32))
    kp = float(ref.kappa_param(q, k, mask))
    sc = ref.softmax_scale(8)
    a = np.exp(np.asarray(q @ k.T) * sc)
    rs = a.sum(-1)
    np.testing.assert_allclose(kp, rs.max() / rs.min(), rtol=1e-5)


def test_alpha_param_uniform_softmax_is_one():
    """For a perfectly uniform softmax matrix, alpha = n * n * (1/n^2) = 1."""
    n = 64
    q = jnp.zeros((n, 8))
    k = jnp.zeros((n, 8))
    assert abs(float(ref.alpha_param(q, k)) - 1.0) < 1e-4


def test_alpha_param_one_hot_is_n():
    """A softmax matrix concentrated on one column has alpha = n."""
    n, d = 32, 8
    q = 10.0 * jnp.ones((n, d))
    k = jnp.zeros((n, d)).at[0].set(10.0 * jnp.ones(d))
    a = float(ref.alpha_param(q, k))
    assert a > 0.9 * n

"""Algorithm 3 (HyperAttention) end-to-end correctness & statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import block_attn, hyper, lsh, ref, sampled
from .conftest import clustered_qkv, rand_qkv


def _run_hyper(q, k, v, *, block, m, seed=0, mode="uniform"):
    d = q.shape[1]
    proj = lsh.projections(jax.random.PRNGKey(seed), d, 8)
    if mode == "vnorm":
        vn = jnp.sum(v * v, axis=-1)
        p = vn / jnp.sum(vn)
        idx = jax.random.choice(jax.random.PRNGKey(seed + 1), q.shape[0],
                                shape=(m,), p=p)
    else:
        idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (m,), 0,
                                 q.shape[0])
    return hyper.hyper_attention(q, k, v, proj, idx, block=block,
                                 sample_mode=mode)


def test_hyper_block_plus_exact_residual_is_exact():
    """Replacing the sampled residual with the dense unmasked part must
    reproduce exact attention to machine precision — validates every
    permutation, mask, and merge in the pipeline."""
    n, d, b = 128, 16, 32
    q, k, v = rand_qkv(21, n, d)
    proj = lsh.projections(jax.random.PRNGKey(22), d, 8)
    perm_q, _ = lsh.sort_permutation(q, proj)
    perm_k, _ = lsh.sort_permutation(k, proj)
    pos_q, pos_k = jnp.argsort(perm_q), jnp.argsort(perm_k)

    mb, sb, nb = block_attn.block_diag_parts(
        q[perm_q], k[perm_k], v[perm_k], block=b)
    p_blk = (mb[pos_q], sb[pos_q], nb[pos_q])

    mask = lsh.block_mask_dense(perm_q, perm_k, n, b)
    sc = ref.softmax_scale(d)
    logits = (q @ k.T) * sc
    me = jnp.max(jnp.where(mask == 0, logits, -1e30), axis=-1)
    pe = (1 - mask) * jnp.exp(logits - me[:, None])
    p_res = (me, jnp.sum(pe, -1), pe @ v)

    out = ref.finalize(ref.merge_parts(p_blk, p_res))
    exp = ref.attention_exact(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["uniform", "vnorm"])
def test_hyper_spectral_error_decreases_with_m(mode):
    """Lemma 2: more samples => tighter Eq. (1) spectral error (on average)."""
    q, k, v = clustered_qkv(23, 256, 32)
    errs = []
    for m in [16, 64, 256]:
        # average over seeds to tame sampling noise
        es = [float(ref.spectral_error(
            _run_hyper(q, k, v, block=32, m=m, seed=s, mode=mode), q, k, v))
            for s in range(3)]
        errs.append(np.mean(es))
    assert errs[2] < errs[0], f"errors not decreasing: {errs}"


def test_hyper_spectral_guarantee_moderate_m():
    """Eq. (1) holds with a practical epsilon at m = n/2 on clustered data."""
    q, k, v = clustered_qkv(24, 256, 32)
    out = _run_hyper(q, k, v, block=64, m=128)
    err = float(ref.spectral_error(out, q, k, v))
    assert err < 0.5, f"spectral error {err}"


def test_hyper_full_sampling_near_exact():
    """With every column sampled many times the estimate concentrates."""
    n = 128
    q, k, v = clustered_qkv(25, n, 16, n_clusters=4, spread=0.1)
    outs = [_run_hyper(q, k, v, block=32, m=4 * n, seed=s) for s in range(4)]
    out = jnp.mean(jnp.stack(outs), axis=0)
    exp = ref.attention_exact(q, k, v)
    rel = float(jnp.linalg.norm(out - exp) / jnp.linalg.norm(exp))
    assert rel < 0.35, f"rel error {rel}"


def test_hyper_preserves_shape_dtype():
    q, k, v = rand_qkv(26, 64, 8)
    out = _run_hyper(q, k, v, block=16, m=16)
    assert out.shape == (64, 8)
    assert out.dtype == q.dtype
    assert bool(jnp.all(jnp.isfinite(out)))


def test_hyper_rows_are_convex_combinations():
    """Each output row must lie in the convex hull of V rows (all weights
    positive and normalized) — holds for the estimator by construction."""
    q, k, v = rand_qkv(27, 64, 4)
    out = np.asarray(_run_hyper(q, k, v, block=16, m=64))
    vmin, vmax = np.asarray(v).min(0), np.asarray(v).max(0)
    assert np.all(out >= vmin - 1e-4)
    assert np.all(out <= vmax + 1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([64, 128]), d=st.sampled_from([8, 16, 32]),
       block=st.sampled_from([16, 32]), seed=st.integers(0, 1000))
def test_hyper_hypothesis_finite_and_shaped(n, d, block, seed):
    q, k, v = rand_qkv(seed, n, d)
    out = _run_hyper(q, k, v, block=block, m=32, seed=seed)
    assert out.shape == (n, d)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_hyper_seeded_wrapper_deterministic():
    q, k, v = rand_qkv(28, 64, 16)
    a = hyper.hyper_attention_seeded(q, k, v, 42, block=16, n_samples=32)
    b = hyper.hyper_attention_seeded(q, k, v, 42, block=16, n_samples=32)
    assert_allclose(np.asarray(a), np.asarray(b))
    c = hyper.hyper_attention_seeded(q, k, v, 43, block=16, n_samples=32)
    assert not np.allclose(np.asarray(a), np.asarray(c))


def test_hyper_multihead_matches_per_head():
    q, k, v = rand_qkv(29, 64, 16)
    qh = jnp.stack([q, q + 0.1])
    kh = jnp.stack([k, k - 0.1])
    vh = jnp.stack([v, v * 2])
    out = hyper.hyper_attention_mh(qh, kh, vh, 5, block=16, n_samples=32)
    one = hyper.hyper_attention_seeded(qh[0], kh[0], vh[0], 5, block=16,
                                       n_samples=32)
    assert out.shape == (2, 64, 16)
    assert_allclose(np.asarray(out[0]), np.asarray(one), atol=1e-5)

"""AOT round-trip: HLO text parses back and executes with correct numerics.

This is the python half of the interchange contract with rust/src/runtime;
the rust integration tests exercise the same artifacts via the xla crate.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_complete(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    for n in aot.ATTN_SIZES:
        for kind in ["attn_exact", "attn_exact_causal", "attn_hyper",
                     "attn_hyper_causal"]:
            assert f"{kind}_{n}" in names
    for p in aot.LM_PATCH:
        assert f"lm_loss_{aot.LM_N}_p{p}" in names
    assert manifest["format"] == "hlo-text"


def test_manifest_paths_exist(manifest):
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["path"])), a["path"]


def test_hlo_text_parses(manifest):
    """Every artifact must be parseable HLO text (non-empty ENTRY)."""
    for a in manifest["artifacts"]:
        with open(os.path.join(ART, a["path"])) as f:
            text = f.read()
        assert "ENTRY" in text and "ROOT" in text, a["name"]


def _execute_hlo(path, args):
    """Compile HLO text with the local CPU client and run it.

    Mirrors the Rust runtime's load path (HLO text -> module -> compile),
    proving the interchange format is executable outside the jax trace.
    """
    with open(path) as f:
        text = f.read()
    dev = jax.devices("cpu")[0]
    backend = dev.client
    comp = xc.XlaComputation(
        xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto())
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    exe = backend.compile_and_load(mlir, [dev])
    out = exe.execute([backend.buffer_from_pyval(a) for a in args])
    return [np.asarray(o) for o in out]


def test_exact_artifact_numerics(manifest):
    """attn_exact_128 output == oracle exact attention."""
    n, h, d = 128, aot.HEADS, aot.DIM
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (h, n, d), jnp.float32)
    k = jax.random.normal(kk, (h, n, d), jnp.float32)
    v = jax.random.normal(kv, (h, n, d), jnp.float32)
    path = os.path.join(ART, f"attn_exact_{n}.hlo.txt")
    out = _execute_hlo(path, [np.asarray(q), np.asarray(k), np.asarray(v)])
    got = out[0].reshape(h, n, d)
    exp = np.stack([np.asarray(ref.attention_exact(q[i], k[i], v[i]))
                    for i in range(h)])
    np.testing.assert_allclose(got, exp, atol=5e-5, rtol=5e-5)


def test_hyper_artifact_runs_and_finite(manifest):
    n, h, d = 128, aot.HEADS, aot.DIM
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = np.asarray(jax.random.normal(kq, (h, n, d), jnp.float32))
    k = np.asarray(jax.random.normal(kk, (h, n, d), jnp.float32))
    v = np.asarray(jax.random.normal(kv, (h, n, d), jnp.float32))
    path = os.path.join(ART, f"attn_hyper_{n}.hlo.txt")
    out = _execute_hlo(path, [q, k, v, np.int32(7)])
    got = out[0].reshape(h, n, d)
    assert np.all(np.isfinite(got))


def test_lm_artifact_loss_matches_direct(manifest):
    """lm_loss_256_p0 == direct jax loss with the same baked params."""
    from compile import model as model_mod

    cfg = model_mod.ModelConfig(
        d_model=64, n_heads=4, n_layers=4, d_ff=256, max_seq=aot.LM_N,
        hyper_block=32, hyper_samples=32, hyper_base=64)
    params = model_mod.init_params(cfg, seed=0)
    toks = jax.random.randint(jax.random.PRNGKey(2), (aot.LM_N,), 0, 256)
    direct = float(model_mod.loss_fn(cfg, params, toks, n_patched=0))
    path = os.path.join(ART, f"lm_loss_{aot.LM_N}_p0.hlo.txt")
    out = _execute_hlo(path, [np.asarray(toks, np.int32), np.int32(0)])
    # different compile pipelines (traced-jit vs HLO-text roundtrip) fuse
    # differently; ~0.2% is fp32 reassociation noise on a 256-term mean
    np.testing.assert_allclose(float(out[0].reshape(())), direct, rtol=1e-2)

"""Core kernel-vs-oracle correctness: flash, block-diag, sampled kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import block_attn, ref, sampled
from .conftest import rand_qkv


# ---------------------------------------------------------------------------
# flash (streaming exact) kernel vs naive exact oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [64, 128, 256])
@pytest.mark.parametrize("causal", [False, True])
def test_flash_matches_exact(n, causal):
    q, k, v = rand_qkv(7, n, 32)
    out = block_attn.flash_attention(q, k, v, causal=causal)
    exp = ref.attention_exact(q, k, v, causal=causal)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_flash_block_shape_invariance(bq, bk):
    """Output must not depend on the tiling."""
    q, k, v = rand_qkv(8, 128, 16)
    out = block_attn.flash_attention(q, k, v, block_q=bq, block_k=bk)
    exp = ref.attention_exact(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
def test_flash_parts_match_exact_parts(causal):
    q, k, v = rand_qkv(9, 128, 16)
    m, s, num = block_attn.flash_attention_parts(q, k, v, causal=causal)
    out = np.asarray(num / np.maximum(np.asarray(s), 1e-30)[:, None])
    exp = ref.attention_exact(q, k, v, causal=causal)
    assert_allclose(out, np.asarray(exp), atol=2e-5, rtol=2e-5)
    # the unnormalized row sums must match exp-space row sums
    rs = np.asarray(s) * np.exp(np.asarray(m))
    exp_rs = np.asarray(ref.row_sums_exact(q, k, causal=causal))
    assert_allclose(rs, exp_rs, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
    causal=st.booleans(),
    scale=st.sampled_from([None, 0.5, 1.0]),
)
def test_flash_hypothesis_sweep(n, d, seed, causal, scale):
    """Hypothesis sweep over shapes/seeds/scales: flash == exact always."""
    q, k, v = rand_qkv(seed, n, d)
    out = block_attn.flash_attention(q, k, v, causal=causal, scale=scale)
    exp = ref.attention_exact(q, k, v, causal=causal, scale=scale)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=5e-5, rtol=5e-5)


def test_flash_rectangular_kv():
    """Queries shorter than keys (the causal off-diagonal block shape)."""
    q, _, _ = rand_qkv(1, 64, 16)
    _, k, v = rand_qkv(2, 128, 16)
    out = block_attn.flash_attention(q, k, v)
    exp = ref.attention_exact(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_flash_extreme_logits_stable():
    """Large-magnitude inputs must not overflow (streaming max shift)."""
    q, k, v = rand_qkv(3, 64, 16, scale=20.0)
    out = np.asarray(block_attn.flash_attention(q, k, v))
    assert np.all(np.isfinite(out))
    exp = np.asarray(ref.attention_exact(q, k, v))
    assert_allclose(out, exp, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# block-diagonal kernel vs dense masked oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,b", [(64, 16), (128, 32), (256, 64), (64, 64)])
def test_block_diag_matches_dense_mask(n, b):
    q, k, v = rand_qkv(11, n, 16)
    m, s, num = block_attn.block_diag_parts(q, k, v, block=b)
    sc = ref.softmax_scale(16)
    logits = np.asarray((q @ k.T)) * sc
    groups = np.arange(n) // b
    mask = (groups[:, None] == groups[None, :])
    lm = np.where(mask, logits, -1e30)
    em = lm.max(-1)
    p = np.where(mask, np.exp(lm - em[:, None]), 0.0)
    assert_allclose(np.asarray(m), em, atol=1e-5)
    assert_allclose(np.asarray(s), p.sum(-1), rtol=1e-5)
    assert_allclose(np.asarray(num), p @ np.asarray(v), rtol=1e-4, atol=1e-5)


def test_block_diag_requires_divisible():
    q, k, v = rand_qkv(0, 96, 8)
    with pytest.raises(AssertionError):
        block_attn.block_diag_parts(q, k, v, block=64)


# ---------------------------------------------------------------------------
# sampled residual kernel vs dense weighted oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(64, 16), (128, 64), (128, 128)])
def test_sampled_kernel_matches_dense(n, m):
    q, k, v = rand_qkv(13, n, 16)
    key = jax.random.PRNGKey(5)
    idx = jax.random.randint(key, (m,), 0, n)
    w = jax.random.uniform(jax.random.PRNGKey(6), (n, m))
    mm, ss, nn = sampled.sampled_parts(q, k[idx], v[idx], w)
    sc = ref.softmax_scale(16)
    logits = np.asarray(q @ k[idx].T) * sc
    em = logits.max(-1)
    p = np.asarray(w) * np.exp(logits - em[:, None])
    assert_allclose(np.asarray(mm), em, atol=1e-5)
    assert_allclose(np.asarray(ss), p.sum(-1), rtol=1e-4)
    assert_allclose(np.asarray(nn), p @ np.asarray(v[idx]), rtol=1e-3, atol=1e-4)


def test_sampled_zero_weights_give_zero():
    q, k, v = rand_qkv(14, 64, 8)
    idx = jnp.arange(16)
    w = jnp.zeros((64, 16))
    _, ss, nn = sampled.sampled_parts(q, k[idx], v[idx], w)
    assert float(jnp.max(jnp.abs(ss))) == 0.0
    assert float(jnp.max(jnp.abs(nn))) == 0.0


def test_residual_weights_drop_own_block():
    """Samples landing in the query's own block must get weight zero."""
    n, b, m = 64, 16, 32
    pos = jnp.arange(n)  # identity permutations
    idx = jnp.arange(m)
    w = sampled.residual_weights(idx, pos, pos, n, b)
    w = np.asarray(w)
    for i in range(n):
        for j in range(m):
            same_block = (i // b) == (int(idx[j]) // b)
            if same_block:
                assert w[i, j] == 0.0
            else:
                assert w[i, j] > 0.0


def test_residual_weights_uniform_scale():
    """Kept weights of one row must sum to ~(n - b)."""
    n, b, m = 128, 32, 64
    pos = jnp.arange(n)
    idx = jax.random.randint(jax.random.PRNGKey(0), (m,), 0, n)
    w = np.asarray(sampled.residual_weights(idx, pos, pos, n, b))
    sums = w.sum(-1)
    assert_allclose(sums[sums > 0], n - b, rtol=1e-5)


# ---------------------------------------------------------------------------
# triple merge algebra
# ---------------------------------------------------------------------------

def test_merge_parts_exact_split():
    """Splitting the key set and merging must equal the unsplit triple."""
    q, k, v = rand_qkv(17, 64, 16)
    p_full = ref.attention_parts_exact(q, k, v)
    p1 = ref.attention_parts_exact(q, k[:32], v[:32])
    p2 = ref.attention_parts_exact(q, k[32:], v[32:])
    merged = ref.merge_parts(p1, p2)
    out_a = np.asarray(ref.finalize(merged))
    out_b = np.asarray(ref.finalize(p_full))
    assert_allclose(out_a, out_b, atol=2e-5, rtol=2e-5)


def test_merge_parts_commutative():
    q, k, v = rand_qkv(18, 32, 8)
    p1 = ref.attention_parts_exact(q, k[:16], v[:16])
    p2 = ref.attention_parts_exact(q, k[16:], v[16:])
    a = np.asarray(ref.finalize(ref.merge_parts(p1, p2)))
    b = np.asarray(ref.finalize(ref.merge_parts(p2, p1)))
    assert_allclose(a, b, atol=1e-6)


def test_finalize_zero_denominator_safe():
    m = jnp.zeros(4)
    s = jnp.zeros(4)
    num = jnp.ones((4, 8))
    out = np.asarray(ref.finalize((m, s, num)))
    assert np.all(np.isfinite(out))

"""AOT bridge: lower every serving artifact to HLO TEXT + a JSON manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/load_hlo/ for the smoke-verified pattern.

Artifacts (all pure functions of their inputs; randomness enters as an
int32 seed input, so the Rust coordinator controls reproducibility):

  attn_exact_{n}            (q,k,v: f32[h,n,d])          -> f32[h,n,d]
  attn_exact_causal_{n}     (q,k,v)                      -> f32[h,n,d]
  attn_hyper_{n}            (q,k,v, seed: i32)           -> f32[h,n,d]
  attn_hyper_causal_{n}     (q,k,v, seed: i32)           -> f32[h,n,d]
  lm_loss_{n}_p{l}          (tokens: i32[n], seed: i32)  -> f32[] CE loss
                            (model params baked in as constants)

`make artifacts` runs this once; Python never runs at serve time.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import block_attn, causal as causal_k, hyper, ref

# Serving-artifact geometry: PJRT-CPU with interpret-mode Pallas is the
# correctness path, so shapes stay modest; the Rust substrate covers the
# large-n performance path (DESIGN.md section 6).
HEADS = 4
DIM = 64
ATTN_SIZES = (128, 256, 512)
HYPER_BLOCK = 32
HYPER_SAMPLES = 64
HYPER_BASE = 128
LM_N = 256
LM_PATCH = (0, 2, 4)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _attn_exact_mh(q, k, v, *, causal: bool):
    fn = functools.partial(block_attn.flash_attention, causal=causal)
    return (jax.vmap(fn)(q, k, v),)


def _attn_hyper_mh(q, k, v, seed):
    return (hyper.hyper_attention_mh(
        q, k, v, seed, block=HYPER_BLOCK, n_samples=HYPER_SAMPLES),)


def _attn_hyper_causal_mh(q, k, v, seed):
    return (causal_k.causal_hyper_attention_mh(
        q, k, v, seed, base=HYPER_BASE, block=HYPER_BLOCK,
        n_samples=HYPER_SAMPLES),)


def build_artifacts():
    """Yield (name, lowered, meta) for every artifact."""
    f32 = jnp.float32
    i32 = jnp.int32

    for n in ATTN_SIZES:
        spec = jax.ShapeDtypeStruct((HEADS, n, DIM), f32)
        seed_spec = jax.ShapeDtypeStruct((), i32)
        meta_common = {"heads": HEADS, "n": n, "d": DIM}

        yield (f"attn_exact_{n}",
               jax.jit(functools.partial(_attn_exact_mh, causal=False), keep_unused=True)
               .lower(spec, spec, spec),
               {"kind": "attn_exact", "causal": False,
                "inputs": ["q", "k", "v"], **meta_common})
        yield (f"attn_exact_causal_{n}",
               jax.jit(functools.partial(_attn_exact_mh, causal=True), keep_unused=True)
               .lower(spec, spec, spec),
               {"kind": "attn_exact", "causal": True,
                "inputs": ["q", "k", "v"], **meta_common})
        yield (f"attn_hyper_{n}",
               jax.jit(_attn_hyper_mh, keep_unused=True).lower(spec, spec, spec, seed_spec),
               {"kind": "attn_hyper", "causal": False,
                "inputs": ["q", "k", "v", "seed"],
                "block": HYPER_BLOCK, "samples": HYPER_SAMPLES,
                **meta_common})
        yield (f"attn_hyper_causal_{n}",
               jax.jit(_attn_hyper_causal_mh, keep_unused=True).lower(spec, spec, spec, seed_spec),
               {"kind": "attn_hyper", "causal": True,
                "inputs": ["q", "k", "v", "seed"],
                "block": HYPER_BLOCK, "samples": HYPER_SAMPLES,
                "base": HYPER_BASE, **meta_common})

    # LM loss artifacts: params baked in as constants (weights are
    # deterministic from seed 0; the Rust model substrate mirrors them).
    cfg = model_mod.ModelConfig(
        d_model=64, n_heads=4, n_layers=4, d_ff=256, max_seq=LM_N,
        hyper_block=32, hyper_samples=32, hyper_base=64)
    params = model_mod.init_params(cfg, seed=0)
    tok_spec = jax.ShapeDtypeStruct((LM_N,), jnp.int32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
    for n_patched in LM_PATCH:
        def lm_fn(tokens, seed, _np=n_patched):
            return (model_mod.loss_fn(cfg, params, tokens, n_patched=_np,
                                      seed=seed),)

        yield (f"lm_loss_{LM_N}_p{n_patched}",
               jax.jit(lm_fn, keep_unused=True).lower(tok_spec, seed_spec),
               {"kind": "lm_loss", "n": LM_N, "patched": n_patched,
                "layers": cfg.n_layers, "inputs": ["tokens", "seed"],
                "d_model": cfg.d_model, "vocab": cfg.vocab})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name prefixes to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = args.only.split(",") if args.only else None
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, lowered, meta in build_artifacts():
        if only and not any(name.startswith(p) for p in only):
            continue
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "path": path, **meta})
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: tiny causal transformer LM with patchable attention.

Mirrors the paper's monkey-patching experiment (Section 4.1): a standard
pre-LN transformer where the FINAL `n_patched` attention layers run
causal HyperAttention (Algorithm 4) instead of exact attention.  The
Rust model substrate (rust/src/model/) implements the same architecture
with the same initialization scheme so artifacts and the pure-Rust path
agree structurally.

Build-time only: lowered by aot.py to HLO text; never imported at serve
time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import block_attn, causal as causal_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256            # byte-level tokenizer
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 2048
    # HyperAttention parameters for patched layers
    hyper_block: int = 64
    hyper_samples: int = 64
    hyper_base: int = 128       # causal recursion base case
    lsh_bits: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, Any]:
    """Deterministic init; scheme mirrored structure-wise in Rust."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 4 + 6 * cfg.n_layers)
    it = iter(keys)

    def dense(k, fan_in, fan_out):
        return jax.random.normal(k, (fan_in, fan_out)) / math.sqrt(fan_in)

    params: dict[str, Any] = {
        "tok_emb": jax.random.normal(next(it), (cfg.vocab, cfg.d_model)) * 0.02,
        "pos_emb": jax.random.normal(next(it), (cfg.max_seq, cfg.d_model)) * 0.02,
        "ln_f": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
            "ln2": {"g": jnp.ones(cfg.d_model), "b": jnp.zeros(cfg.d_model)},
            "wqkv": dense(next(it), cfg.d_model, 3 * cfg.d_model),
            "wo": dense(next(it), cfg.d_model, cfg.d_model),
            "w1": dense(next(it), cfg.d_model, cfg.d_ff),
            "w2": dense(next(it), cfg.d_ff, cfg.d_model),
            # biases kept explicit (zero-init) to match the Rust layout
            "b1": jnp.zeros(cfg.d_ff),
            "b2": jnp.zeros(cfg.d_model),
        })
    return params


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, x, layer, *, use_hyper: bool, seed,
               interpret: bool = True, attn_impl: str = "pallas"):
    """Multi-head causal attention; exact (flash) or HyperAttention.

    attn_impl="pallas" uses the L1 kernels (serving artifacts);
    attn_impl="jnp" uses the differentiable oracle (training path —
    interpret-mode pallas_call has no VJP).
    """
    n, _ = x.shape
    qkv = x @ layer["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(t):
        return t.reshape(n, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)  # (h, n, dh)

    if use_hyper and n > cfg.hyper_base:
        out = causal_k.causal_hyper_attention_mh(
            q, k, v, seed, base=cfg.hyper_base, block=cfg.hyper_block,
            n_samples=cfg.hyper_samples, lsh_bits=cfg.lsh_bits,
            interpret=interpret)
    elif attn_impl == "jnp":
        from .kernels import ref as _ref

        out = jax.vmap(
            lambda qh, kh, vh: _ref.attention_exact(qh, kh, vh, causal=True)
        )(q, k, v)
    else:
        out = jax.vmap(
            lambda qh, kh, vh: block_attn.flash_attention(
                qh, kh, vh, causal=True, interpret=interpret))(q, k, v)

    out = out.transpose(1, 0, 2).reshape(n, cfg.d_model)
    return out @ layer["wo"]


def forward(cfg: ModelConfig, params, tokens, *, n_patched: int = 0,
            seed: int = 0, interpret: bool = True, attn_impl: str = "pallas"):
    """Logits (n, vocab) for a token sequence (n,) int32.

    The FINAL n_patched layers use causal HyperAttention, matching the
    paper's patch-from-the-end protocol.
    """
    n = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:n]
    first_patched = cfg.n_layers - n_patched
    for li, layer in enumerate(params["layers"]):
        use_hyper = li >= first_patched
        h = layer_norm(x, layer["ln1"]["g"], layer["ln1"]["b"])
        x = x + _attention(cfg, h, layer, use_hyper=use_hyper,
                           seed=seed + 131 * li, interpret=interpret,
                           attn_impl=attn_impl)
        h = layer_norm(x, layer["ln2"]["g"], layer["ln2"]["b"])
        h = jax.nn.gelu(h @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
        x = x + h
    x = layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    return x @ params["tok_emb"].T


def loss_fn(cfg: ModelConfig, params, tokens, *, n_patched: int = 0,
            seed: int = 0, interpret: bool = True, attn_impl: str = "pallas"):
    """Next-token cross-entropy (mean over positions)."""
    logits = forward(cfg, params, tokens, n_patched=n_patched, seed=seed,
                     interpret=interpret, attn_impl=attn_impl)
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def perplexity(cfg: ModelConfig, params, tokens, **kw):
    return jnp.exp(loss_fn(cfg, params, tokens, **kw))

"""Algorithm 4: recursive causal HyperAttention.

The causal attention matrix splits into three equal-sized non-zero
sections (Fig. 2 of the paper): two half-size *causal* diagonal blocks
(recurse) and one *unmasked* off-diagonal block A_21 (handled by the
non-causal HyperAttention of Algorithm 3).  The recursion bottoms out at
`base`, where the exact streaming (flash) kernel runs with a causal mask.

All parts are streaming-softmax triples, so the second half's output is
the exact merge of its off-diagonal part (queries Q2 vs keys K1) and its
recursive causal part (Q2 vs K2) — no denominator bookkeeping beyond the
triples themselves.

The recursion unrolls at trace time (n is static), giving a single fused
HLO for the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import block_attn, hyper, ref


def _concat_parts(p1, p2):
    """Stack triples of the two query halves (disjoint query rows)."""
    m1, s1, n1 = p1
    m2, s2, n2 = p2
    return (jnp.concatenate([m1, m2]), jnp.concatenate([s1, s2]),
            jnp.concatenate([n1, n2]))


def causal_hyper_parts(q, k, v, seed, *, base: int, block: int,
                       n_samples: int, lsh_bits: int = 8,
                       scale: float | None = None,
                       interpret: bool = True, _level: int = 0):
    """Triple of causal HyperAttention over (q, k, v): (n, d) each."""
    n, d = q.shape
    if n <= base:
        return block_attn.flash_attention_parts(
            q, k, v, causal=True, scale=scale, interpret=interpret,
            block_q=min(64, n), block_k=min(64, n))

    half = n // 2
    q1, q2 = q[:half], q[half:]
    k1, k2 = k[:half], k[half:]
    v1, v2 = v[:half], v[half:]

    # Distinct derived seeds per recursion site so samples decorrelate.
    s11 = seed * 3 + 1 + _level
    s22 = seed * 3 + 2 + _level
    s21 = seed * 3 + 3 + _level

    p11 = causal_hyper_parts(
        q1, k1, v1, s11, base=base, block=block, n_samples=n_samples,
        lsh_bits=lsh_bits, scale=scale, interpret=interpret,
        _level=_level + 1)
    p22 = causal_hyper_parts(
        q2, k2, v2, s22, base=base, block=block, n_samples=n_samples,
        lsh_bits=lsh_bits, scale=scale, interpret=interpret,
        _level=_level + 1)

    # Off-diagonal block A_21 is unmasked: non-causal HyperAttention.
    import jax

    key = jax.random.PRNGKey(s21)
    kp, ksamp = jax.random.split(key)
    from . import lsh as _lsh

    proj = _lsh.projections(kp, d, lsh_bits, dtype=q.dtype)
    m_eff = min(n_samples, half)
    sample_idx = jax.random.randint(ksamp, (m_eff,), 0, half)
    p21 = hyper.hyper_attention_parts(
        q2, k1, v1, proj, sample_idx, block=min(block, half),
        scale=scale, interpret=interpret)

    p2 = ref.merge_parts(p21, p22)
    return _concat_parts(p11, p2)


def causal_hyper_attention(q, k, v, seed, *, base: int, block: int,
                           n_samples: int, lsh_bits: int = 8,
                           scale: float | None = None,
                           interpret: bool = True):
    """Normalized causal HyperAttention output (n, d)."""
    parts = causal_hyper_parts(
        q, k, v, seed, base=base, block=block, n_samples=n_samples,
        lsh_bits=lsh_bits, scale=scale, interpret=interpret)
    return ref.finalize(parts)


def causal_hyper_attention_mh(q, k, v, seed, *, base: int, block: int,
                              n_samples: int, lsh_bits: int = 8,
                              scale: float | None = None,
                              interpret: bool = True):
    """Multi-head causal wrapper: (h, n, d) inputs, per-head seeds."""
    import jax

    h = q.shape[0]
    seeds = seed + 1000 * jnp.arange(h, dtype=jnp.int32)

    def one(qh, kh, vh, sh):
        return causal_hyper_attention(
            qh, kh, vh, sh, base=base, block=block, n_samples=n_samples,
            lsh_bits=lsh_bits, scale=scale, interpret=interpret)

    return jax.vmap(one)(q, k, v, seeds)

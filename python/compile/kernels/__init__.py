"""Layer-1 kernels: Pallas hot-spots + pure-jnp oracles for HyperAttention."""

from . import approx_d, block_attn, causal, hyper, lsh, ref, sampled  # noqa: F401

"""Algorithm 3: HyperAttention forward (non-causal), practical variant.

Pipeline (the paper's Section 4 "Implementation Detail"):
  1. Hash Q and K rows with Hamming-sorted LSH; sort each by bucket.
  2. Exact attention inside equal-sized diagonal blocks of the sorted
     attention matrix (the mask M^H of Algorithm 1) — Pallas kernel.
  3. Estimate the unmasked remainder of each row (both the D row sum and
     the product with V) from m uniformly sampled key/value rows shared
     across queries — Pallas kernel with per-row weights that drop
     samples falling in the query's own block.
  4. Merge the two streaming-softmax triples and normalize.

All functions take explicit randomness (projection matrix + sample
indices) so the AOT artifacts are pure functions of their inputs; the
seed-based wrapper generates both from an int32 seed inside the trace.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import block_attn, lsh, ref, sampled


def hyper_attention_parts(q, k, v, proj, sample_idx, *, block: int,
                          scale: float | None = None,
                          sample_mode: str = "uniform",
                          interpret: bool = True):
    """Streaming triple (m, s, N) of HyperAttention, in original row order.

    q, k, v: (n, d) (n divisible by block); proj: (d, r) LSH hyperplanes;
    sample_idx: (m,) int32 indices into the original key rows.
    """
    n, d = q.shape
    assert k.shape[0] == n, "hyper attention requires len(q) == len(k)"
    assert n % block == 0

    perm_q, _ = lsh.sort_permutation(q, proj)
    perm_k, _ = lsh.sort_permutation(k, proj)
    pos_q = jnp.argsort(perm_q)  # original row -> sorted position
    pos_k = jnp.argsort(perm_k)

    qs = q[perm_q]
    ks = k[perm_k]
    vs = v[perm_k]

    # (2) exact block-diagonal part, in sorted order -> back to original.
    mb, sb, nb = block_attn.block_diag_parts(
        qs, ks, vs, block=block, scale=scale, interpret=interpret)
    mb, sb, nb = mb[pos_q], sb[pos_q], nb[pos_q]

    # (3) sampled residual over the unmasked columns.
    w = sampled.residual_weights(
        sample_idx, pos_q, pos_k, n, block,
        v=v if sample_mode == "vnorm" else None, mode=sample_mode)
    ms, ss, ns = sampled.sampled_parts(
        q, k[sample_idx], v[sample_idx], w, scale=scale, interpret=interpret)

    # (4) merge.
    return ref.merge_parts((mb, sb, nb), (ms, ss, ns))


def hyper_attention(q, k, v, proj, sample_idx, *, block: int,
                    scale: float | None = None,
                    sample_mode: str = "uniform",
                    interpret: bool = True):
    """HyperAttention output (n, d): normalized Algorithm 3."""
    parts = hyper_attention_parts(
        q, k, v, proj, sample_idx, block=block, scale=scale,
        sample_mode=sample_mode, interpret=interpret)
    return ref.finalize(parts)


def hyper_attention_seeded(q, k, v, seed, *, block: int, n_samples: int,
                           lsh_bits: int = 8, scale: float | None = None,
                           sample_mode: str = "uniform",
                           interpret: bool = True):
    """Seed-based entry point used by the AOT artifacts.

    seed: int32 scalar.  LSH projections and sample indices are derived
    from it inside the trace (threefry), so the artifact signature is
    (q, k, v, seed) with fixed shapes.
    """
    n, d = q.shape
    key = jax.random.PRNGKey(seed)
    kp, ks = jax.random.split(key)
    proj = lsh.projections(kp, d, lsh_bits, dtype=q.dtype)
    if sample_mode == "vnorm":
        vn = jnp.sum(v * v, axis=-1)
        probs = vn / jnp.maximum(jnp.sum(vn), 1e-30)
        sample_idx = jax.random.choice(ks, n, shape=(n_samples,), p=probs)
    else:
        sample_idx = jax.random.randint(ks, (n_samples,), 0, n)
    return hyper_attention(
        q, k, v, proj, sample_idx, block=block, scale=scale,
        sample_mode=sample_mode, interpret=interpret)


def hyper_attention_mh(q, k, v, seed, *, block: int, n_samples: int,
                       lsh_bits: int = 8, scale: float | None = None,
                       interpret: bool = True):
    """Multi-head wrapper: q, k, v of shape (h, n, d); vmapped over heads.

    Each head gets a distinct derived seed so LSH projections differ.
    """
    h = q.shape[0]
    seeds = seed + jnp.arange(h, dtype=jnp.int32)

    def one(qh, kh, vh, sh):
        return hyper_attention_seeded(
            qh, kh, vh, sh, block=block, n_samples=n_samples,
            lsh_bits=lsh_bits, scale=scale, interpret=interpret)

    return jax.vmap(one)(q, k, v, seeds)

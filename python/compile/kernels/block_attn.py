"""Pallas kernels: block-diagonal attention and streaming (flash) exact attention.

These are the Layer-1 compute hot-spots.  Both kernels are written for the
TPU mental model (tiles pulled HBM->VMEM via BlockSpec, MXU-shaped block
matmuls) but are lowered with interpret=True so they execute as plain HLO
on the CPU PJRT backend (see DESIGN.md section 7, Hardware-Adaptation).

Kernels return streaming-softmax triples (m, s, N) per query row (see
ref.py) so the coordinator / callers can merge parts across key subsets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Block-diagonal attention (the sortLSH "heavy entries" part of Algorithm 3)
# ---------------------------------------------------------------------------

def _block_diag_kernel(q_ref, k_ref, v_ref, m_ref, s_ref, n_ref, *, scale):
    """One grid step = one diagonal block: full attention inside the block.

    q_ref/k_ref/v_ref: (b, d) VMEM tiles of the LSH-sorted Q, K, V.
    The (b, d) x (d, b) product is the MXU-shaped hot matmul.
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    logits = jnp.dot(q, k.T) * scale  # (b, b)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[:, None])
    m_ref[...] = m
    s_ref[...] = jnp.sum(p, axis=-1)
    n_ref[...] = jnp.dot(p, v)


def block_diag_parts(qs, ks, vs, *, block: int, scale: float | None = None,
                     interpret: bool = True):
    """Streaming triples of the block-diagonal attention over sorted inputs.

    qs, ks, vs: (n, d) rows already sorted by LSH bucket; n % block == 0.
    Returns (m, s, num) with shapes ((n,), (n,), (n, d)) in sorted order.
    """
    n, d = qs.shape
    assert n % block == 0, f"n={n} not divisible by block={block}"
    nb = n // block
    sc = ref.softmax_scale(d, scale)
    kern = functools.partial(_block_diag_kernel, scale=sc)
    grid = (nb,)
    row_spec = pl.BlockSpec((block, d), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    m, s, num = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec],
        out_specs=[vec_spec, vec_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), qs.dtype),
            jax.ShapeDtypeStruct((n,), qs.dtype),
            jax.ShapeDtypeStruct((n, d), qs.dtype),
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return m, s, num


# ---------------------------------------------------------------------------
# Streaming-softmax exact attention (the FlashAttention stand-in)
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k,
                  causal, nk):
    """One grid step = one query tile; stream all key tiles through VMEM.

    On TPU the fori_loop body is the double-buffered HBM->VMEM pipeline
    over K/V tiles; the (block_q, d) x (d, block_k) products hit the MXU.
    """
    i = pl.program_id(0)
    q = q_ref[...]  # (block_q, d)
    d = q.shape[1]
    nblk = nk // block_k

    def body(j, carry):
        m, s, num = carry
        ks = pl.load(k_ref, (pl.dslice(j * block_k, block_k), pl.dslice(0, d)))
        vs = pl.load(v_ref, (pl.dslice(j * block_k, block_k), pl.dslice(0, d)))
        logits = jnp.dot(q, ks.T) * scale
        if causal:
            qi = i * block_q + jnp.arange(block_q)[:, None]
            kj = j * block_k + jnp.arange(block_k)[None, :]
            logits = jnp.where(qi >= kj, logits, NEG_INF)
        bm = jnp.max(logits, axis=-1)
        m2 = jnp.maximum(m, bm)
        e_old = jnp.exp(m - m2)
        p = jnp.exp(logits - m2[:, None])
        s2 = s * e_old + jnp.sum(p, axis=-1)
        num2 = num * e_old[:, None] + jnp.dot(p, vs)
        return m2, s2, num2

    m0 = jnp.full((block_q,), NEG_INF, q.dtype)
    s0 = jnp.zeros((block_q,), q.dtype)
    n0 = jnp.zeros_like(q)
    m, s, num = jax.lax.fori_loop(0, nblk, body, (m0, s0, n0))
    o_ref[...] = num / jnp.maximum(s, 1e-30)[:, None]


def flash_attention(q, k, v, *, block_q: int = 64, block_k: int = 64,
                    causal: bool = False, scale: float | None = None,
                    interpret: bool = True):
    """Exact attention with FlashAttention's streaming-softmax structure.

    q: (n, d); k, v: (nk, d).  Returns (n, d).
    """
    n, d = q.shape
    nk = k.shape[0]
    block_q = min(block_q, n)
    block_k = min(block_k, nk)
    assert n % block_q == 0 and nk % block_k == 0
    sc = ref.softmax_scale(d, scale)
    kern = functools.partial(
        _flash_kernel, scale=sc, block_q=block_q, block_k=block_k,
        causal=causal, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            # K/V stay whole-array resident; the fori_loop streams tiles.
            pl.BlockSpec((nk, d), lambda i: (0, 0)),
            pl.BlockSpec((nk, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out


def flash_attention_parts(q, k, v, *, block_q: int = 64, block_k: int = 64,
                          causal: bool = False, scale: float | None = None,
                          interpret: bool = True):
    """Triple-form flash attention: like flash_attention but returns (m,s,N).

    Used as the causal-recursion base case, where the caller still needs to
    merge with the off-diagonal parts.
    """
    n, d = q.shape
    nk = k.shape[0]
    block_q = min(block_q, n)
    block_k = min(block_k, nk)
    assert n % block_q == 0 and nk % block_k == 0
    sc = ref.softmax_scale(d, scale)

    def kern(q_ref, k_ref, v_ref, m_ref, s_ref, n_ref):
        i = pl.program_id(0)
        qt = q_ref[...]
        nblk = nk // block_k

        def body(j, carry):
            m, s, num = carry
            ks = pl.load(k_ref, (pl.dslice(j * block_k, block_k), pl.dslice(0, d)))
            vs = pl.load(v_ref, (pl.dslice(j * block_k, block_k), pl.dslice(0, d)))
            logits = jnp.dot(qt, ks.T) * sc
            if causal:
                qi = i * block_q + jnp.arange(block_q)[:, None]
                kj = j * block_k + jnp.arange(block_k)[None, :]
                logits = jnp.where(qi >= kj, logits, NEG_INF)
            bm = jnp.max(logits, axis=-1)
            m2 = jnp.maximum(m, bm)
            e_old = jnp.exp(m - m2)
            p = jnp.exp(logits - m2[:, None])
            return m2, s * e_old + jnp.sum(p, -1), num * e_old[:, None] + jnp.dot(p, vs)

        m0 = jnp.full((block_q,), NEG_INF, qt.dtype)
        s0 = jnp.zeros((block_q,), qt.dtype)
        n0 = jnp.zeros_like(qt)
        m, s, num = jax.lax.fori_loop(0, nblk, body, (m0, s0, n0))
        m_ref[...] = m
        s_ref[...] = s
        n_ref[...] = num

    m, s, num = pl.pallas_call(
        kern,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((nk, d), lambda i: (0, 0)),
            pl.BlockSpec((nk, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), q.dtype),
            jax.ShapeDtypeStruct((n,), q.dtype),
            jax.ShapeDtypeStruct((n, d), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v)
    return m, s, num

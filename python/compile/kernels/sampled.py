"""Pallas kernel: weighted column-sampled attention residual.

This is the "uniform sampling" half of Algorithm 3 / Lemma 2: the row sum
of the unmasked part of A and the product with V are estimated from m
sampled key/value rows shared across all queries (the paper's
Implementation Detail in Section 4).  Per-query weights w_ij (zero for
samples that fall inside the query's own sortLSH diagonal block, an
inverse-probability scale otherwise) are computed by the caller and
passed in, so the kernel itself is a pure weighted streaming-softmax.

TPU mapping: the grid tiles the query rows; the m sampled K/V rows stay
VMEM-resident across all grid steps (the analogue of the paper keeping
the sample in SRAM); the (tile, d) x (d, m) product is MXU-shaped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sampled_kernel(q_ref, ks_ref, vs_ref, w_ref, m_ref, s_ref, n_ref, *, scale):
    q = q_ref[...]        # (tile, d)
    ks = ks_ref[...]      # (m, d) — VMEM resident
    vs = vs_ref[...]      # (m, d)
    w = w_ref[...]        # (tile, m) — per-(row, sample) weights
    logits = jnp.dot(q, ks.T) * scale  # (tile, m)
    m = jnp.max(logits, axis=-1)
    p = w * jnp.exp(logits - m[:, None])
    m_ref[...] = m
    s_ref[...] = jnp.sum(p, axis=-1)
    n_ref[...] = jnp.dot(p, vs)


def sampled_parts(q, k_samp, v_samp, weights, *, tile: int = 64,
                  scale: float | None = None, interpret: bool = True):
    """Streaming triples of the weighted sampled residual.

    q: (n, d); k_samp, v_samp: (m, d) sampled rows; weights: (n, m).
    Returns (m, s, num) per query row.  Note: m is the max over ALL sampled
    logits (including zero-weight ones) — still a valid triple since s and
    num are weighted consistently; merging with other parts stays exact.
    """
    n, d = q.shape
    msamp = k_samp.shape[0]
    tile = min(tile, n)
    assert n % tile == 0
    sc = ref.softmax_scale(d, scale)
    kern = functools.partial(_sampled_kernel, scale=sc)
    m, s, num = pl.pallas_call(
        kern,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((msamp, d), lambda i: (0, 0)),
            pl.BlockSpec((msamp, d), lambda i: (0, 0)),
            pl.BlockSpec((tile, msamp), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), q.dtype),
            jax.ShapeDtypeStruct((n,), q.dtype),
            jax.ShapeDtypeStruct((n, d), q.dtype),
        ],
        interpret=interpret,
    )(q, k_samp, v_samp, weights)
    return m, s, num


def residual_weights(sample_idx, pos_q, pos_k, n: int, block: int,
                     v: jnp.ndarray | None = None,
                     mode: str = "uniform"):
    """Per-(query, sample) weights for the unmasked-residual estimator.

    sample_idx: (m,) indices into the ORIGINAL key rows (shared across
    queries).  pos_q/pos_k: (n,) sorted positions of each original row
    (inverse sortLSH permutations).  A sample j is dropped for query i when
    it falls in i's diagonal block (those entries are counted exactly by
    the block kernel).

    mode="uniform": ratio estimator; kept samples are scaled by
        (n - block) / (#kept for that row), estimating the sum over the
        n - block unmasked columns.
    mode="vnorm": Lemma 2 row-norm sampling; the caller sampled idx with
        probability p_j ∝ ||V_j||²; weight is 1/(m p_j) (Horvitz-Thompson).
    """
    gq = pos_q // block                       # (n,) query block ids
    gk_samp = pos_k[sample_idx] // block      # (m,) sampled-key block ids
    keep = (gq[:, None] != gk_samp[None, :]).astype(jnp.float32)  # (n, m)
    if mode == "uniform":
        cnt = jnp.maximum(jnp.sum(keep, axis=-1, keepdims=True), 1.0)
        return keep * (n - block) / cnt
    elif mode == "vnorm":
        assert v is not None
        vn = jnp.sum(v * v, axis=-1)
        probs = vn / jnp.maximum(jnp.sum(vn), 1e-30)
        w = 1.0 / (sample_idx.shape[0] * jnp.maximum(probs[sample_idx], 1e-30))
        return keep * w[None, :]
    raise ValueError(f"unknown sampling mode {mode!r}")

"""Algorithm 2 (ApproxD): spectral estimation of the diagonal matrix D.

This is the *faithful* transcription of the paper's Algorithm 2, kept in
unnormalized exp space (valid for test-scale logits; the production path
in hyper.py uses the numerically-safe streaming-triple formulation, which
is algebraically the same estimator).  It exists so that (a) the Lemma 1
guarantee can be tested directly against the exact D, and (b) the Rust
substrate's approx_d module has a cross-language oracle.

Steps (line numbers match the paper):
  3: tau   = max unmasked row sum over a random row subset T, |T| = m
  4: l_1..l_m ~ Unif([n]) shared sample columns
  6: C_i  = cap = theta * (masked row sum + tau/kappa),
             theta = eps^2 m / (n log n)
  7: d_i  = (n/m) * sum_j (1 - M_{i,l_j}) min(exp(<q_i, k_{l_j}>), C_i)
  8: d~_i = masked row sum + max(d_i, tau/kappa)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import ref


def masked_row_sums(q, k, mask, *, scale: float | None = None):
    """<M_i, exp(K q_i)> for all i — exact sums over the masked entries."""
    sc = ref.softmax_scale(q.shape[1], scale)
    a = jnp.exp((q @ k.T) * sc)
    return jnp.sum(mask * a, axis=-1)


def approx_d(key, q, k, mask, *, kappa: float, eps: float, m: int,
             scale: float | None = None, theta_const: float = 1.0):
    """Algorithm 2.  mask: dense (n, n) in {0,1} (test scale).

    Returns d_tilde (n,) — the estimated row sums of A (the D diagonal).
    """
    n, d = q.shape
    sc = ref.softmax_scale(d, scale)
    key_t, key_l = jax.random.split(key)

    a = jnp.exp((q @ k.T) * sc)                    # (n, n) — test scale only
    unmasked = (1.0 - mask) * a

    # line 3: tau from a random row subset of size m
    rows = jax.random.choice(key_t, n, shape=(min(m, n),), replace=False)
    tau = jnp.max(jnp.sum(unmasked[rows], axis=-1))

    # line 4: shared uniform column samples
    samp = jax.random.randint(key_l, (m,), 0, n)

    masked_sums = jnp.sum(mask * a, axis=-1)       # <M_i, A_i>

    # line 6: per-row cap
    theta = theta_const * (eps * eps * m) / (n * math.log(max(n, 2)))
    c = theta * (masked_sums + tau / kappa)        # (n,)

    # line 7: capped uniform estimate of the unmasked row sum
    vals = a[:, samp]                              # (n, m)
    keep = 1.0 - mask[:, samp]
    capped = jnp.minimum(vals, c[:, None])
    d_est = (n / m) * jnp.sum(keep * capped, axis=-1)

    # line 8: lower capping at tau/kappa
    return masked_sums + jnp.maximum(d_est, tau / kappa)


def approx_d_error(d_tilde, q, k, *, scale: float | None = None):
    """Spectral error of Eq. (2): ||(D~^-1 - D^-1) A||_op / ||D^-1 A||_op."""
    sc = ref.softmax_scale(q.shape[1], scale)
    a = jnp.exp((q @ k.T) * sc)
    dd = jnp.sum(a, axis=-1)
    lhs = (1.0 / d_tilde - 1.0 / dd)[:, None] * a
    rhs = a / dd[:, None]
    return jnp.linalg.norm(lhs, ord=2) / jnp.maximum(jnp.linalg.norm(rhs, ord=2), 1e-30)

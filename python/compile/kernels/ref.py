"""Pure-jnp reference oracles for HyperAttention kernels.

Everything in this module is the ground truth the Pallas kernels and the
Rust substrate are tested against.  All attention parts are expressed in
the *streaming-softmax triple* representation

    part = (m, s, N)   with, per query row i:
        m_i = max_j logit_ij          (running max, for stability)
        s_i = sum_j w_j exp(logit_ij - m_i)
        N_i = sum_j w_j exp(logit_ij - m_i) * V_j

so that partial results over disjoint key sets can be merged exactly
(`merge_parts`) and the final output is N / s.  This matches the paper's
unnormalized A = exp(QK^T) with D = row sums: s * exp(m) estimates the
row sum of A restricted to the part's key set.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def softmax_scale(d: int, scale: float | None = None) -> float:
    """Default logit scale 1/sqrt(d), overridable."""
    return 1.0 / math.sqrt(d) if scale is None else scale


def attention_exact(q, k, v, *, causal: bool = False, scale: float | None = None):
    """Exact attention D^{-1} A V with A = exp(scale * QK^T).

    q, k, v: (n, d).  Returns (n, d).  Numerically stable softmax.
    """
    _, d = q.shape
    s = softmax_scale(d, scale)
    logits = (q @ k.T) * s
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), dtype=bool))
        logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    a = jnp.exp(logits - m)
    return (a @ v) / jnp.sum(a, axis=-1, keepdims=True)


def attention_parts_exact(q, k, v, *, causal: bool = False, scale: float | None = None):
    """Exact attention in (m, s, N) triple form over the full key set."""
    _, d = q.shape
    sc = softmax_scale(d, scale)
    logits = (q @ k.T) * sc
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), dtype=bool))
        logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    a = jnp.exp(logits - m[:, None])
    s = jnp.sum(a, axis=-1)
    num = a @ v
    return m, s, num


def merge_parts(p1, p2):
    """Merge two streaming-softmax triples over disjoint key sets."""
    m1, s1, n1 = p1
    m2, s2, n2 = p2
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    s = s1 * e1 + s2 * e2
    num = n1 * e1[:, None] + n2 * e2[:, None]
    return m, s, num


def finalize(part, eps: float = 1e-30):
    """Normalize a triple to attention output N / s."""
    _, s, num = part
    return num / jnp.maximum(s, eps)[:, None]


def row_sums_exact(q, k, *, causal: bool = False, scale: float | None = None):
    """Exact D diagonal: row sums of A = exp(scale * QK^T) (masked if causal)."""
    _, d = q.shape
    sc = softmax_scale(d, scale)
    a = jnp.exp((q @ k.T) * sc)
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), dtype=q.dtype))
        a = a * mask
    return jnp.sum(a, axis=-1)


def softmax_matrix(q, k, *, causal: bool = False, scale: float | None = None):
    """D^{-1} A, the row-stochastic softmax matrix (for alpha/kappa checks)."""
    _, d = q.shape
    sc = softmax_scale(d, scale)
    logits = (q @ k.T) * sc
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[0], k.shape[0]), dtype=bool))
        logits = jnp.where(mask, logits, NEG_INF)
    return jax.nn.softmax(logits, axis=-1)


def alpha_param(q, k, *, causal: bool = False, scale: float | None = None,
                exclude_cols: int = 0):
    """Paper's alpha = n * max_i ||D^{-1} A e^{(i)}||_2^2 (Section 4.3).

    exclude_cols drops the first columns (the paper excludes 32 sink
    columns for LM-derived inputs).
    """
    p = softmax_matrix(q, k, causal=causal, scale=scale)
    col_sq = jnp.sum(p * p, axis=0)
    if exclude_cols:
        col_sq = col_sq[exclude_cols:]
    return q.shape[0] * jnp.max(col_sq)


def kappa_param(q, k, mask, *, scale: float | None = None):
    """Paper's kappa: max/min unmasked row sums of A.  mask: (n,n) in {0,1}."""
    _, d = q.shape
    sc = softmax_scale(d, scale)
    a = jnp.exp((q @ k.T) * sc)
    unmasked = jnp.sum((1.0 - mask) * a, axis=-1)
    return jnp.max(unmasked) / jnp.maximum(jnp.min(unmasked), 1e-30)


def flash_exact(q, k, v, *, block: int = 64, causal: bool = False,
                scale: float | None = None):
    """Blocked streaming-softmax exact attention (FlashAttention structure).

    Numerically identical (up to fp error) to attention_exact; exists as
    the oracle for the blocked/streaming formulation the Pallas kernel
    and the Rust flash baseline use.
    """
    n, d = q.shape
    nk = k.shape[0]
    assert nk % block == 0, "key length must be divisible by block"
    sc = softmax_scale(d, scale)
    nblocks = nk // block

    def body(carry, j):
        m, s, num = carry
        ks = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=0)
        vs = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=0)
        logits = (q @ ks.T) * sc
        if causal:
            qi = jnp.arange(n)[:, None]
            kj = j * block + jnp.arange(block)[None, :]
            logits = jnp.where(qi >= kj, logits, NEG_INF)
        bm = jnp.max(logits, axis=-1)
        m2 = jnp.maximum(m, bm)
        e_old = jnp.exp(m - m2)
        p = jnp.exp(logits - m2[:, None])
        s2 = s * e_old + jnp.sum(p, axis=-1)
        num2 = num * e_old[:, None] + p @ vs
        return (m2, s2, num2), None

    init = (jnp.full((n,), NEG_INF, q.dtype), jnp.zeros((n,), q.dtype),
            jnp.zeros((n, d), q.dtype))
    (m, s, num), _ = jax.lax.scan(body, init, jnp.arange(nblocks))
    return num / jnp.maximum(s, 1e-30)[:, None]


def spectral_error(out_approx, q, k, v, *, causal: bool = False,
                   scale: float | None = None):
    """Relative operator-norm error of Eq. (1), via exact SVD (test sizes)."""
    exact = attention_exact(q, k, v, causal=causal, scale=scale)
    err = jnp.linalg.norm(out_approx - exact, ord=2)
    p = softmax_matrix(q, k, causal=causal, scale=scale)
    denom = jnp.linalg.norm(p, ord=2) * jnp.linalg.norm(v, ord=2)
    return err / jnp.maximum(denom, 1e-30)

"""Hamming-sorted LSH (Definition 1 of the paper).

Hash function: r random hyperplanes P in R^{d x r}; the sign pattern of
x @ P is read as a *Gray code*, and the bucket id is the Gray code's rank
(binary value of the Gray-decoded bits).  Gray decoding is what gives the
"Hamming sorted" property: buckets whose ids differ by 1 correspond to
sign patterns at Hamming distance 1, i.e. geometrically adjacent cells,
which is exactly what lets sortLSH concentrate large attention entries
near the diagonal after sorting (Fig. 1 of the paper).

Collision probability for a single hyperplane is 1 - theta/pi; with r
planes, P[H(x) = H(y)] = (1 - theta/pi)^r as in Definition 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def projections(key, d: int, r: int, dtype=jnp.float32):
    """r random hyperplane normals, shape (d, r)."""
    return jax.random.normal(key, (d, r), dtype=dtype)


def gray_to_binary(bits):
    """Decode Gray-code bits (..., r), MSB first, to binary bits.

    b_0 = g_0;  b_i = b_{i-1} XOR g_i.  Implemented as a cumulative XOR,
    i.e. parity of the prefix sum.
    """
    csum = jnp.cumsum(bits.astype(jnp.int32), axis=-1)
    return jnp.mod(csum, 2)


def bucket_ids(x, proj):
    """Hamming-sorted bucket id for each row of x.  Returns (n,) int32.

    x: (n, d), proj: (d, r).  Bucket ids lie in [0, 2^r).
    """
    bits = (x @ proj > 0).astype(jnp.int32)  # (n, r) sign pattern = Gray code
    bin_bits = gray_to_binary(bits)
    r = proj.shape[1]
    weights = (2 ** jnp.arange(r - 1, -1, -1)).astype(jnp.int32)
    return jnp.sum(bin_bits * weights, axis=-1)


def sort_permutation(x, proj):
    """Permutation sorting rows of x by Hamming-sorted bucket id.

    Returns (perm, buckets): x[perm] is sorted by bucket.  Stable, so ties
    keep input order (deterministic given proj).
    """
    b = bucket_ids(x, proj)
    perm = jnp.argsort(b, stable=True)
    return perm, b


def collision_probability(theta, r: int):
    """Definition 1: P[H(x)=H(y)] = (1 - theta/pi)^r."""
    return (1.0 - theta / jnp.pi) ** r


def adjacent_probability(theta, r: int):
    """Definition 1: P[H(x)=H(y) +- 1 mod 2^r]."""
    t = theta / jnp.pi
    return 2.0 * t * (1.0 - t) ** (r - 1)


def block_mask_dense(perm_q, perm_k, n: int, block: int):
    """Dense n x n mask M^H of Algorithm 1 (test-scale only).

    M[i, j] = 1 iff floor(P_Q(i)/b) == floor(P_K(j)/b), where P_Q(i) is the
    *position* of row i after sorting.
    """
    pos_q = jnp.argsort(perm_q)  # inverse permutation: row -> sorted position
    pos_k = jnp.argsort(perm_k)
    gq = pos_q // block
    gk = pos_k // block
    return (gq[:, None] == gk[None, :]).astype(jnp.float32)
